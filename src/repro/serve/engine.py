"""Continuous-batching serving engine on the proxy patterns.

Architecture = the paper's Fig 4 applied to inference:

- requests arrive on a **ProxyStream**: the admission thread consumes
  *metadata only* (request id, prompt length, max tokens) and resolves the
  bulk prompt just-in-time, overlapped with the decode loop;
- each admitted sequence's control-plane state (page list, per-page KV
  cells) is **ownership**-managed (kvcache.PageTable) — completion
  deterministically frees everything, including the store memory;
- results stream back on a response topic as **incremental token deltas**
  (metadata-only events, one per token) plus a final bulk completion
  proxy — a client sees its first token the moment the prefill admits the
  request, not a whole generation later (serve/client.ServeClient
  assembles them).

The engine loop is *notification-driven*: no sleep-poll anywhere.  A puller
thread blocks in the request consumer (broker condition wait / connector
``wait_for`` under PR 3's protocol) and hands requests over a condition
variable; the decode loop blocks on that condition only when every slot is
idle, and otherwise drains admissions between jit'd decode steps (the
decode deadline: an active batch never waits on the request stream).

Decode (``paged=True``, the default) runs over a **page pool**: each
stacked cache leaf is ``(L, P+1, page_size, ...)`` (P = the PageTable's
pages, plus one null scratch page — see kvcache's module docstring for the
layout).  The jit'd step gathers each slot's live pages through its block
table (``pages_of``, null-padded to a power-of-two width so recompiles
stay bounded), decodes every slot at its own position, and scatters back
*only the one page each slot wrote* — donated, so the pool updates in
place and a short sequence touches its own pages, never ``max_len``.
Admission inserts prefilled KV page-by-page (``batch_prefill=True`` admits
up to ``slots`` queued requests in one padded prefill + one donated
multi-page insert), and ``share_prefixes=True`` aliases common prompt
prefixes through the PageTable's refcounted cells — copy-on-write events
are mirrored onto the pool as device page copies before the step that
would diverge.  ``paged=False`` keeps the dense ``(L, B, S, ...)`` layout
(the benchmark baseline, and the fallback for indivisible page sizes).

Admission is backpressured through PageTable reservations: a request is
admitted only when the pool can cover its *whole* generation, so decode
never OOMs mid-sequence; requests the pool can never fit are rejected onto
the response stream as errors.

Speculative decode (``spec_k > 0`` + a ``draft_model``): each step, the
draft proposes up to k tokens per active slot (k+1 chained single-token
steps over its own page pool, re-feeding the previous token so the draft
cache self-heals after full acceptance), then the target verifies all k+1
positions in ONE jit'd paged forward (``verify_batch`` → multi-query paged
attention: query t attends keys < len+t).  Greedy rejection accepts the
longest draft prefix matching the target's own argmaxes plus one corrected
token — emitted tokens are ALWAYS target argmaxes, so the output is
bit-identical to plain greedy decode for any draft; draft quality only
moves the accepted-tokens/step rate.  Rejected draft KV "rolls back" by
never scattering positions past the accepted length into the pool (a
PageTable only grows), and ``k_eff = min(k, remaining-1, horizon)`` clamps
keep every extend inside the admission reservation, so speculation can
never OOM and pricing is unchanged.  The draft runs a second PageTable (its
own Store, no prefix sharing) in lockstep: ``can_admit`` checks both pools
and ``_finish`` frees both.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sanitize as _sanitize
from repro.core.lifetimes import ContextLifetime
from repro.core.proxy import extract
from repro.core.store import Store
from repro.core.streaming import StreamConsumer, StreamProducer
from repro.dist.sharding import ParamSpec, materialize_params, sharding_tree
from repro.models.api import build_model
from repro.models.layers import ModelContext

# How often the puller/idle waits re-check stop/exit flags.  This is NOT a
# poll interval for events — both waits are notification-driven (broker
# condition / connector wait_for) and wake immediately on traffic; the tick
# only bounds how long shutdown can lag.
_WAIT_TICK = 0.25


def serve_context(cfg, mesh=None, *, use_kernels: bool = False) -> ModelContext:
    """ModelContext with the ``serve`` rules profile applied.

    The serve profile shards the KV cache's sequence axis over the model
    axis (``kv_seq`` wins the model axis; decode is KV-bound) — the rules
    flow into both param placement and the cache shardings the engine
    applies in :meth:`ServeEngine._ensure_cache`.
    """
    from repro.launch.mesh import make_host_mesh, rules_for

    mesh = mesh if mesh is not None else make_host_mesh()
    return ModelContext(cfg, mesh, rules_for(mesh, "serve"), use_kernels)


@dataclass
class Request:
    req_id: str
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    arrived: float = field(default_factory=time.perf_counter)


@dataclass
class SlotState:
    req: Request | None = None
    pos: int = 0  # current length (prompt + generated)
    generated: list[int] = field(default_factory=list)
    first_token_at: float | None = None
    pages: list[int] = field(default_factory=list)  # cached block table


class ServeEngine:
    def __init__(
        self,
        ctx: ModelContext,
        params,
        *,
        slots: int = 4,
        max_len: int = 128,
        page_size: int = 16,
        eos_id: int = 0,
        model=None,
        kv_store: Store | None = None,
        paged: bool = True,
        batch_prefill: bool = True,
        share_prefixes: bool = True,
        spec_k: int = 0,
        draft_model=None,
        draft_params=None,
        on_load_change=None,
        done_commit_prefix: str | None = None,
    ):
        from repro.core.connectors import new_key
        from repro.serve.kvcache import PageTable

        self.ctx = ctx
        self.cfg = ctx.cfg
        self.model = model if model is not None else build_model(ctx)
        self.params = params
        self.slots = [SlotState() for _ in range(slots)]
        self.max_len = max_len
        self.eos_id = eos_id
        self._owns_store = kv_store is None
        self.kv_store = kv_store if kv_store is not None else Store(f"kv-{new_key()}")
        self.pages = PageTable(
            num_pages=slots * (max_len // page_size),
            page_size=page_size,
            store=self.kv_store,
            page_bytes=self._page_bytes(page_size),
        )
        # paged decode needs pages to tile max_len exactly; else dense
        self.paged = paged and max_len % page_size == 0
        self.batch_prefill = batch_prefill
        self.share_prefixes = share_prefixes
        # Fleet hooks (serve/router.py).  ``on_load_change(pages_available)``
        # fires after every admission batch and every completion so an
        # engine can publish its capacity as store metadata (the router's
        # least-loaded signal); a failing hook is counted, never fatal.
        # ``done_commit_prefix`` switches completions to the exactly-once
        # ``send_committed`` path: the record lands at the deterministic
        # key ``{prefix}{req_id}`` via put_if_absent, so a redispatched
        # request re-completed by a survivor engine commits ONE payload
        # however many engines finish it.
        self.on_load_change = on_load_change
        self.done_commit_prefix = done_commit_prefix
        # speculative decode: a draft model proposes spec_k tokens per slot
        # per step; the target verifies all of them in one paged forward.
        # Greedy rejection keeps the longest matching prefix plus the
        # target's corrected token, so the emitted stream is bit-identical
        # to target-only greedy decode by construction.
        if spec_k > 0 and draft_model is None:
            raise ValueError("spec_k > 0 requires a draft_model")
        if spec_k > 0 and not self.paged:
            raise ValueError(
                "speculative decode requires the paged cache layout "
                "(max_len must be a multiple of page_size, paged=True)"
            )
        self.spec_k = int(spec_k)
        self.draft_model = draft_model
        self.draft_params = draft_params if draft_params is not None else {}
        self._can_batch = hasattr(self.model, "prefill_batch")
        # pool geometry, pinned at construction (tests may shrink the
        # allocator's num_pages afterwards to force backpressure — the
        # device pool keeps its build-time size, so every id stays valid)
        self._null_page = self.pages.num_pages
        self._pages_per_slot = max(1, max_len // page_size)
        if self.paged:
            self._cache_specs = self._pool_specs()
        else:
            self._cache_specs = self.model.cache_specs(len(self.slots), self.max_len)
        # serve-profile shardings for the cache (kv_seq over the model
        # axis); a no-op placement on the 1-device smoke mesh
        self._cache_shardings = sharding_tree(self._cache_specs, ctx.rules, ctx.mesh)
        if self.spec_k:
            from repro.serve.kvcache import page_bytes_for

            # The draft pool mirrors the target pool's geometry (same page
            # ids, same null page) but is priced off the DRAFT model's
            # per-token cache, and lives in its own store so its page
            # cells never collide with the target's keys.  No prefix
            # sharing on the draft side: its KV is advisory (drafts only
            # steer acceptance, never the emitted tokens).
            self._draft_store = Store(f"kvdraft-{new_key()}")
            self.draft_pages = PageTable(
                num_pages=self.pages.num_pages,
                page_size=page_size,
                store=self._draft_store,
                page_bytes=page_bytes_for(draft_model, self.cfg.dtype, page_size),
            )
            self._draft_cache_specs = self._pool_specs(draft_model)
            self._draft_shardings = sharding_tree(
                self._draft_cache_specs, ctx.rules, ctx.mesh
            )
        else:
            self._draft_store = None
            self.draft_pages = None
        # cache donated on the per-token hot path too: the step rewrites
        # the KV buffers in place instead of allocating a full copy per
        # token (self._cache is reassigned from the result, so the donated
        # input is never reused)
        if self.paged:
            self._decode = jax.jit(self._decode_paged_body, donate_argnums=(1,))
        else:
            self._decode = jax.jit(self._decode_body, donate_argnums=(1,))
        # per-slot cache insert: donated so XLA updates the batch buffers in
        # place; the slot index / page ids are traced, so one compilation
        # covers every admission target instead of re-lowering per slot
        self._admit_cache = jax.jit(self._admit_body, donate_argnums=(0,))
        self._insert_pages = jax.jit(self._insert_body, donate_argnums=(0,))
        self._copy_page = jax.jit(self._copy_body, donate_argnums=(0,))
        self._prefill = jax.jit(
            lambda p, tokens: self.model.prefill(p, tokens, self.max_len)
        )
        if self._can_batch:
            self._prefill_many = jax.jit(
                lambda p, tokens, lens: self.model.prefill_batch(
                    p, tokens, lens, self.max_len
                )
            )
        if self.spec_k:
            self._spec_draft = jax.jit(self._spec_draft_body, donate_argnums=(1,))
            self._spec_verify = jax.jit(self._spec_verify_body, donate_argnums=(1,))
            self._draft_prefill = jax.jit(
                lambda p, tokens: self.draft_model.prefill(p, tokens, self.max_len)
            )
            if hasattr(self.draft_model, "prefill_batch"):
                self._draft_prefill_many = jax.jit(
                    lambda p, tokens, lens: self.draft_model.prefill_batch(
                        p, tokens, lens, self.max_len
                    )
                )
        self._cache = None  # paged: (L, P+1, ps, ...); dense: (L, B, S, ...)
        self._draft_cache = None  # spec_k only: draft model's page pool
        self._live_prompts: dict[str, np.ndarray] = {}  # for prefix sharing
        # Per-request lifetimes, split by custodian.  Request-side payloads
        # (persistent prompt bulks) are consumed by THIS engine, so close()
        # always reclaims them.  Response-side payloads (completion bulks)
        # are custody shared with the client: a resolving client reclaims
        # them itself (one-shot stream contract), and close() sweeps only
        # what no client claimed — unless the response stream outlives the
        # engine (restart handoff), see close(reclaim_responses=False).
        self._req_lifetimes: dict[str, ContextLifetime] = {}
        self._resp_lifetimes: dict[str, ContextLifetime] = {}
        self.completed: dict[str, dict] = {}
        self.rejected: dict[str, str] = {}
        self.metrics = {
            "prefills": 0,
            "decode_steps": 0,
            "tokens": 0,
            "loop_iters": 0,
            "idle_waits": 0,
            "queued_admissions": 0,
            "max_pending": 0,
            "malformed_events": 0,
            "batched_prefills": 0,
            "prefix_shared_pages": 0,
            "cow_page_copies": 0,
            "spec_steps": 0,
            "spec_slot_steps": 0,
            "spec_accepted_tokens": 0,
            "reclaim_failures": 0,
            "load_publish_failures": 0,
        }

    def _page_bytes(self, page_size: int) -> int:
        """Host-side KV bytes one page represents (the PageTable cell size)."""
        from repro.serve.kvcache import page_bytes_for

        return page_bytes_for(self.model, self.cfg.dtype, page_size)

    def _pool_specs(self, model=None):
        """Page-pool cache specs: each dense (L, B, S, ...) leaf becomes
        (L, P+1, page_size, ...) — axis 1 is the physical page id (the
        last index is the null scratch page), axis 2 the in-page offset."""
        per_page = (model or self.model).cache_specs(1, self.pages.page_size)
        P = self._null_page + 1

        def to_pool(s):
            return ParamSpec(
                (s.shape[0], P) + s.shape[2:],
                (s.axes[0], "kv_seq", None) + s.axes[3:],
                s.dtype,
                s.init_scale,
            )

        return jax.tree.map(
            to_pool, per_page, is_leaf=lambda x: isinstance(x, ParamSpec)
        )

    # -- model glue ---------------------------------------------------------
    def _decode_body(self, params, cache, tokens, lens):
        """Dense-layout decode: each slot at its own index via vmap over
        the batch axis (the ``paged=False`` baseline path)."""

        def one(cache_b, tok_b, len_b):
            c = jax.tree.map(lambda x: x[:, None], cache_b)  # re-add batch dim
            logits, nc = self.model.decode_step(params, c, tok_b[None], len_b)
            return jax.tree.map(lambda x: x[:, 0], nc), logits[0]

        new_cache, logits = jax.vmap(
            one, in_axes=(1, 0, 0), out_axes=(1, 0)
        )(cache, tokens, lens)
        return new_cache, logits

    def _decode_paged_body(self, params, pool, bt, tokens, lens):
        """Paged decode: gather each slot's pages into a contiguous view,
        decode every slot at its own position, scatter back **only the one
        page each slot wrote** (the model's decode contract: the step
        writes position ``lens[b]`` and nothing else).

        ``bt`` (B, n) is the null-padded block table; n is the power-of-two
        page coverage of the longest active slot, so the gathered view —
        and the attention the model runs inside it — scales with what the
        batch actually occupies, not with max_len."""
        ps = self.pages.page_size
        B, n = bt.shape

        def gather(leaf):
            g = leaf[:, bt]  # (L, B, n, ps, ...)
            return g.reshape(g.shape[:2] + (n * ps,) + g.shape[4:])

        dense = jax.tree.map(gather, pool)

        def one(cache_b, tok_b, len_b):
            c = jax.tree.map(lambda x: x[:, None], cache_b)
            logits, nc = self.model.decode_step(params, c, tok_b[None], len_b)
            return jax.tree.map(lambda x: x[:, 0], nc), logits[0]

        new_dense, logits = jax.vmap(
            one, in_axes=(1, 0, 0), out_axes=(1, 0)
        )(dense, tokens, lens)

        page_slot = lens // ps  # (B,) block-table index of the written page
        dst = jnp.take_along_axis(bt, page_slot[:, None], axis=1)[:, 0]  # (B,)

        def pick(nd_b, p_idx):  # (L, n*ps, ...) → the written (L, ps, ...)
            return jax.lax.dynamic_slice_in_dim(nd_b, p_idx * ps, ps, axis=1)

        def scatter(leaf, nd):
            written = jax.vmap(pick, in_axes=(1, 0), out_axes=1)(nd, page_slot)
            return leaf.at[:, dst].set(written.astype(leaf.dtype))

        return jax.tree.map(scatter, pool, new_dense), logits

    def _admit_body(self, cache, one, slot_idx):
        """Dense path: insert a (batch=1) prefill cache at slot
        ``slot_idx`` — a dynamic per-slot update on donated buffers."""
        return jax.tree.map(
            lambda full, o: jax.lax.dynamic_update_index_in_dim(
                full, o[:, 0].astype(full.dtype), slot_idx, 1
            ),
            cache,
            one,
        )

    def _insert_body(self, pool, caches, page_ids):
        """Paged admission insert: ``caches`` (L, Bk, max_len, ...) from
        prefill, viewed as (L, Bk*pages_per_slot, page_size, ...) pages;
        ``page_ids`` (Bk*pages_per_slot,) their physical destinations.
        Pad rows, unowned tails, and *shared (borrowed) prefix pages* all
        point at the null page — the insert never writes a page another
        sequence owns."""
        ps = self.pages.page_size

        def one(pool_leaf, c):
            mp = c.shape[2] // ps
            cp = c.reshape((c.shape[0], c.shape[1] * mp, ps) + c.shape[3:])
            return pool_leaf.at[:, page_ids].set(cp.astype(pool_leaf.dtype))

        return jax.tree.map(one, pool, caches)

    def _copy_body(self, pool, src, dst):
        """Copy-on-write mirror: duplicate physical page src → dst."""
        return jax.tree.map(lambda leaf: leaf.at[:, dst].set(leaf[:, src]), pool)

    # -- speculative decode (spec_k > 0) -------------------------------------
    #
    # Per step, three phases over the same block-table machinery as
    # _decode_paged_body:
    #   draft:  k+1 chained single-token steps on the DRAFT pool propose
    #           d_1..d_k per slot (the first step re-feeds the previous
    #           token so a fully-accepted run's bonus token is caught up —
    #           rewriting position pos-1 with the same token is a no-op);
    #   verify: ONE multi-position target forward feeds [last, d_1..d_k]
    #           at positions pos..pos+k and computes the acceptance length
    #           in-graph: a = LCP(draft, target argmax) + 1 — the emitted
    #           tokens are ALWAYS the target's argmaxes, so the stream is
    #           bit-identical to target-only greedy decode;
    #   rollback: pages past the accepted length are simply not scattered
    #           back (dst redirected to the null page) — the PageTable
    #           never rolls back, and stale draft-side bytes are rewritten
    #           by the next step before anything attends them.
    #
    # Per-slot speculation depth k_eff clamps to (remaining-1, max_len-2-pos)
    # so every extend stays inside the admission-time reservation; rows pad
    # their draft tokens with -1 beyond k_eff, which can never match an
    # argmax, capping acceptance exactly at k_eff+1.

    def _gather_dense(self, pool, bt):
        """(L, P+1, ps, ...) pool → contiguous (L, B, n*ps, ...) view."""
        ps = self.pages.page_size
        n = bt.shape[1]

        def gather(leaf):
            g = leaf[:, bt]
            return g.reshape(g.shape[:2] + (n * ps,) + g.shape[4:])

        return jax.tree.map(gather, pool)

    def _scatter_span(self, pool, dense, bt, first, last):
        """Scatter pages ``first[b]..last[b]`` of each row's dense view back
        to their physical pages; rows/pages outside the span write the null
        scratch page.  The static write bound covers the k+1 positions one
        speculative step can touch."""
        ps = self.pages.page_size
        n = bt.shape[1]
        n_wr = min(n, (self.spec_k + ps - 1) // ps + 1)

        def pick(nd_b, p_idx):  # (L, n*ps, ...) → page p_idx's (L, ps, ...)
            return jax.lax.dynamic_slice_in_dim(nd_b, p_idx * ps, ps, axis=1)

        for j in range(n_wr):
            slot_j = jnp.clip(first + j, 0, n - 1)  # (B,)
            keep = (first + j >= 0) & (first + j <= last)
            dstp = jnp.take_along_axis(bt, slot_j[:, None], axis=1)[:, 0]
            dst = jnp.where(keep, dstp, self._null_page)

            def scatter(leaf, nd):
                written = jax.vmap(pick, in_axes=(1, 0), out_axes=1)(nd, slot_j)
                return leaf.at[:, dst].set(written.astype(leaf.dtype))

            pool = jax.tree.map(scatter, pool, dense)
        return pool

    def _spec_draft_body(self, draft_params, pool, bt, prev, last, lens, k_eff):
        """Draft proposal: k+1 chained decode steps on the draft pool.

        Step 0 re-feeds ``prev`` at position lens-1 (catch-up: after a
        fully-accepted run the draft cache is one token behind the
        target's; otherwise the rewrite is byte-identical).  Step 1 feeds
        ``last`` at lens and yields d_1; step j>=2 chains the argmax.
        Returns (new_pool, drafts (B, k)) — drafts are advisory only."""
        dense = self._gather_dense(pool, bt)

        def one(cache_b, tok_b, idx_b):
            c = jax.tree.map(lambda x: x[:, None], cache_b)
            logits, nc = self.draft_model.decode_step(
                draft_params, c, tok_b[None, None], idx_b
            )
            return jax.tree.map(lambda x: x[:, 0], nc), logits[0]

        step = jax.vmap(one, in_axes=(1, 0, 0), out_axes=(1, 0))
        cur = prev
        drafts = []
        for j in range(self.spec_k + 1):
            dense, logits = step(dense, cur, lens - 1 + j)
            nxt = jnp.argmax(
                logits[:, : self.cfg.vocab], axis=-1
            ).astype(jnp.int32)
            if j == 0:
                cur = last  # step 0's output is `last` itself: known
            else:
                drafts.append(nxt)
                cur = nxt
        # persist positions lens-1 .. lens-1+k_eff (catch-up + the drafts
        # the verify pass may accept); later writes are scratch
        first = (lens - 1) // self.pages.page_size
        lastp = (lens - 1 + k_eff) // self.pages.page_size
        pool = self._scatter_span(pool, dense, bt, first, lastp)
        return pool, jnp.stack(drafts, axis=1)

    def _spec_verify_body(self, params, pool, bt, tokens, lens, k_eff):
        """Target verify: ONE multi-position forward over the paged pool.

        ``tokens`` (B, k+1) = [last, d_1..d_k] per row (-1 beyond k_eff),
        landing at positions lens..lens+k.  Acceptance and rollback are
        in-graph: a = LCP + 1, and only pages holding accepted positions
        scatter back — everything past them is dropped on the floor."""
        dense = self._gather_dense(pool, bt)
        logits, new_dense = self.model.verify_batch(params, dense, tokens, lens)
        out = jnp.argmax(logits[..., : self.cfg.vocab], axis=-1).astype(
            jnp.int32
        )  # (B, k+1): out[:, t] corrects/extends after fed token t
        match = (out[:, :-1] == tokens[:, 1:]).astype(jnp.int32)  # (B, k)
        acc = jnp.cumprod(match, axis=1).sum(axis=1) + 1  # (B,) in 1..k+1
        acc = jnp.minimum(acc, k_eff + 1)  # -1 padding already enforces this
        first = lens // self.pages.page_size
        lastp = (lens + acc - 1) // self.pages.page_size
        pool = self._scatter_span(pool, new_dense, bt, first, lastp)
        return pool, out, acc

    def _ensure_cache(self):
        if self._cache is None:
            cache = materialize_params(self._cache_specs, jax.random.PRNGKey(0))
            self._cache = jax.device_put(cache, self._cache_shardings)
        if self.spec_k and self._draft_cache is None:
            cache = materialize_params(
                self._draft_cache_specs, jax.random.PRNGKey(0)
            )
            self._draft_cache = jax.device_put(cache, self._draft_shardings)

    def _apply_cow(self):
        """Mirror queued PageTable copy-on-write events on the device pool
        and refresh the affected slot's cached block table."""
        for seq, src, dst in self.pages.drain_cow_events():
            self._ensure_cache()
            self._cache = self._copy_page(
                self._cache, jnp.int32(src), jnp.int32(dst)
            )
            self.metrics["cow_page_copies"] += 1
            for s in self.slots:
                if s.req is not None and s.req.req_id == seq:
                    s.pages = self.pages.pages_of(seq)

    def _bt_width(self, needed: int) -> int:
        """Block-table width: next power of two ≥ the widest active slot's
        page coverage (capped at a full slot) — recompiles stay O(log)."""
        n = 1
        while n < needed:
            n *= 2
        return min(n, max(self._pages_per_slot, 1))

    def _bt_width_spec(self, needed: int) -> int:
        """Speculative block-table width: like _bt_width, but capped one
        burst wider than a full slot.  The verify forward WRITES k+1
        positions starting at pos regardless of per-row k_eff, and
        ``dynamic_update_slice`` clamps out-of-range starts — a too-narrow
        gathered view would silently shift those writes onto valid KV.  The
        extra columns are null pages: written as scratch, never scattered."""
        cap = -(-(self.max_len + self.spec_k) // self.pages.page_size)
        n = 1
        while n < needed:
            n *= 2
        return min(max(n, 1), max(cap, 1))

    # -- request admission --------------------------------------------------
    def _prefix_parent(self, prompt: np.ndarray) -> tuple[str | None, int]:
        """Longest-common-prefix live sequence to share pages with (must
        cover at least one full page to be worth a refcount)."""
        if not (self.paged and self.share_prefixes):
            return None, 0
        best, best_l = None, 0
        for sid, pp in self._live_prompts.items():
            if sid not in self.pages.live_sequences():
                continue
            m = min(len(pp), len(prompt))
            if m <= best_l:
                continue
            neq = np.nonzero(pp[:m] != prompt[:m])[0]
            l = int(neq[0]) if len(neq) else m
            if l > best_l:
                best, best_l = sid, l
        if best_l >= self.pages.page_size:
            return best, best_l
        return None, 0

    def _allocate_for(self, req: Request) -> None:
        """Claim (and possibly share) pages for ``req`` — the admission
        decision; device-side prefill/insert happens in _insert_prefill."""
        total = min(len(req.prompt) + req.max_new_tokens, self.max_len)
        parent, ptok = self._prefix_parent(req.prompt)
        if parent is not None:
            self.pages.allocate(
                req.req_id, len(req.prompt), reserve_tokens=total,
                prefix_of=parent, prefix_tokens=ptok,
            )
            self.metrics["prefix_shared_pages"] += len(
                self.pages.borrowed_pages(req.req_id)
            )
        else:
            self.pages.allocate(req.req_id, len(req.prompt), reserve_tokens=total)
        if self.spec_k:
            # lockstep draft allocation (no sharing: draft KV is advisory);
            # keep the two pools atomic — a draft-side failure must not
            # leave a half-admitted sequence holding target pages
            try:
                self.draft_pages.allocate(
                    req.req_id, len(req.prompt), reserve_tokens=total
                )
            except BaseException:
                self.pages.free_sequence(req.req_id)
                raise
        self._live_prompts[req.req_id] = np.asarray(req.prompt, np.int32)

    def _slot_ids_row(self, req_id: str, table=None) -> np.ndarray:
        """Physical destination pages for one admitted row's insert: owned
        pages in token order; borrowed (shared-prefix) pages and the
        unallocated tail map to the null page."""
        table = table if table is not None else self.pages
        ids = np.full((self._pages_per_slot,), self._null_page, np.int32)
        borrowed = table.borrowed_pages(req_id)
        for j, p in enumerate(table.pages_of(req_id)):
            if p not in borrowed:
                ids[j] = p
        return ids

    def _insert_prefill(self, batch: list[tuple[Request, int]]) -> list[int]:
        """Prefill + device insert for admitted requests; returns each
        request's first token (from the prefill logits).  One padded
        prefill and one donated multi-page insert cover the whole batch on
        the paged path; the dense path and non-batching models insert one
        request at a time."""
        firsts: list[int] = []
        self._ensure_cache()
        if self.paged:
            self._apply_cow()  # allocate-time COW copies land before insert
        if self.paged and self._can_batch and (
            self.batch_prefill or len(batch) > 1
        ):
            B = len(self.slots)
            mp = self._pages_per_slot
            sp = max(len(req.prompt) for req, _ in batch)
            tokens = np.zeros((B, sp), np.int32)
            lens = np.ones((B,), np.int32)  # pad rows decode garbage, unread
            ids = np.full((B * mp,), self._null_page, np.int32)
            for req, slot_idx in batch:
                tokens[slot_idx, : len(req.prompt)] = req.prompt
                lens[slot_idx] = len(req.prompt)
                ids[slot_idx * mp : (slot_idx + 1) * mp] = self._slot_ids_row(
                    req.req_id
                )
            logits, caches = self._prefill_many(
                self.params, jnp.asarray(tokens), jnp.asarray(lens)
            )
            self._cache = self._insert_pages(self._cache, caches, jnp.asarray(ids))
            if self.spec_k:
                ids_d = np.full((B * mp,), self._null_page, np.int32)
                for req, slot_idx in batch:
                    ids_d[slot_idx * mp : (slot_idx + 1) * mp] = (
                        self._slot_ids_row(req.req_id, self.draft_pages)
                    )
                if hasattr(self.draft_model, "prefill_batch"):
                    _, dcaches = self._draft_prefill_many(
                        self.draft_params, jnp.asarray(tokens), jnp.asarray(lens)
                    )
                    self._draft_cache = self._insert_pages(
                        self._draft_cache, dcaches, jnp.asarray(ids_d)
                    )
                else:
                    for req, slot_idx in batch:
                        prompt = jnp.asarray(req.prompt[None], jnp.int32)
                        _, dcache1 = self._draft_prefill(self.draft_params, prompt)
                        self._draft_cache = self._insert_pages(
                            self._draft_cache,
                            dcache1,
                            jnp.asarray(
                                self._slot_ids_row(req.req_id, self.draft_pages)
                            ),
                        )
            if len(batch) > 1:
                self.metrics["batched_prefills"] += 1
            logits_np = np.asarray(logits, np.float32)
            firsts = [
                int(np.argmax(logits_np[slot_idx, : self.cfg.vocab]))
                for _, slot_idx in batch
            ]
        else:
            for req, slot_idx in batch:
                prompt = jnp.asarray(req.prompt[None], jnp.int32)
                logits, cache1 = self._prefill(self.params, prompt)
                if self.paged:
                    ids = self._slot_ids_row(req.req_id)
                    self._cache = self._insert_pages(
                        self._cache, cache1, jnp.asarray(ids)
                    )
                    if self.spec_k:
                        _, dcache1 = self._draft_prefill(self.draft_params, prompt)
                        self._draft_cache = self._insert_pages(
                            self._draft_cache,
                            dcache1,
                            jnp.asarray(
                                self._slot_ids_row(req.req_id, self.draft_pages)
                            ),
                        )
                else:
                    self._cache = self._admit_cache(
                        self._cache, cache1, jnp.int32(slot_idx)
                    )
                firsts.append(
                    int(np.argmax(np.asarray(logits[0, : self.cfg.vocab], np.float32)))
                )
        now = time.perf_counter()
        for (req, slot_idx), first in zip(batch, firsts):
            slot = self.slots[slot_idx]
            slot.req = req
            # pos = KV entries in the cache; the first token's KV is
            # written by the decode step that consumes it
            slot.pos = len(req.prompt)
            slot.generated = [first]
            slot.first_token_at = now
            slot.pages = self.pages.pages_of(req.req_id) if self.paged else []
            self.metrics["prefills"] += 1
            self.metrics["tokens"] += 1
        self._notify_load()
        return firsts

    def _notify_load(self) -> None:
        """Publish current capacity through the ``on_load_change`` hook.

        A broken publish channel (store server briefly unreachable) must
        not abort the serve loop — the failure is counted so it is never
        silent, and the next admission/completion retries naturally.
        """
        if self.on_load_change is None:
            return
        try:
            self.on_load_change(self.pages.pages_available())
        except BaseException:
            self.metrics["load_publish_failures"] += 1

    def _request_lifetime(self, req_id: str) -> ContextLifetime:
        lt = self._req_lifetimes.get(req_id)
        if lt is None:
            lt = self._req_lifetimes[req_id] = ContextLifetime()
        return lt

    def _response_lifetime(self, req_id: str) -> ContextLifetime:
        lt = self._resp_lifetimes.get(req_id)
        if lt is None:
            lt = self._resp_lifetimes[req_id] = ContextLifetime()
        return lt

    def admit(self, req: Request, slot_idx: int) -> int:
        """Admit one request into ``slot_idx``; returns its *first* token.

        The first generated token comes from the prefill logits — it exists
        the moment the request is admitted, before any decode step (the
        decode loop's job is tokens 2..n, each fed back at its own per-slot
        position).
        """
        self._allocate_for(req)
        return self._insert_prefill([(req, slot_idx)])[0]

    def _finish(self, slot_idx: int):
        slot = self.slots[slot_idx]
        req = slot.req
        self.pages.free_sequence(req.req_id)  # ownership free → pages + store
        if self.spec_k:
            self.draft_pages.free_sequence(req.req_id)
        self._live_prompts.pop(req.req_id, None)
        now = time.perf_counter()
        self.completed[req.req_id] = {
            "tokens": list(slot.generated),
            "latency": now - req.arrived,
            "ttft": (slot.first_token_at or now) - req.arrived,
        }
        slot.req = None
        slot.pos = 0
        slot.generated = []
        slot.first_token_at = None
        slot.pages = []
        self._notify_load()

    def _spec_decode_step(self, active, send_delta, finish_if_done):
        """One speculative engine step over the active slots: draft k
        proposals per slot, verify all of them in one target forward, emit
        the accepted run (target argmaxes — bit-identical to plain greedy).

        Per-slot depth ``k_eff`` clamps speculation to what the request can
        still accept (remaining-1) and to the cache horizon (max_len-2-pos),
        so both pools' extends stay inside the admission reservation.  A
        k_eff of 0 degenerates to an exact single-token decode step."""
        k = self.spec_k
        B = len(self.slots)
        prev = np.zeros((B,), np.int32)
        last = np.zeros((B,), np.int32)
        lens = np.ones((B,), np.int32)  # idle rows decode garbage at pos 0
        k_eff = np.zeros((B,), np.int32)
        for i in active:
            s = self.slots[i]
            g = len(s.generated)
            remaining = s.req.max_new_tokens - g
            k_eff[i] = max(0, min(k, remaining - 1, self.max_len - 2 - s.pos))
            last[i] = s.generated[-1]
            prev[i] = s.generated[-2] if g >= 2 else int(s.req.prompt[-1])
            lens[i] = s.pos
            # both pools must own every page a fully-accepted run writes
            # BEFORE the step (extend within the reservation never fails)
            if self.pages.extend(s.req.req_id, s.pos + int(k_eff[i]) + 1):
                s.pages = self.pages.pages_of(s.req.req_id)
            self.draft_pages.extend(s.req.req_id, s.pos + int(k_eff[i]))
        self._apply_cow()
        width = self._bt_width_spec(max(
            self.pages.pages_needed(self.slots[i].pos + k + 1) for i in active
        ))
        bt = np.full((B, width), self._null_page, np.int32)
        bt_d = np.full((B, width), self._null_page, np.int32)
        for i in active:
            s = self.slots[i]
            m = min(len(s.pages), width)
            bt[i, :m] = s.pages[:m]
            dpages = self.draft_pages.pages_of(s.req.req_id)
            md = min(len(dpages), width)
            bt_d[i, :md] = dpages[:md]
        self._ensure_cache()
        self._draft_cache, drafts = self._spec_draft(
            self.draft_params, self._draft_cache, jnp.asarray(bt_d),
            jnp.asarray(prev), jnp.asarray(last), jnp.asarray(lens),
            jnp.asarray(k_eff),
        )
        drafts_np = np.asarray(drafts, np.int32)  # (B, k)
        ver = np.full((B, k + 1), -1, np.int32)
        ver[:, 0] = last
        for i in active:  # -1 beyond k_eff never matches an argmax
            ver[i, 1 : 1 + k_eff[i]] = drafts_np[i, : k_eff[i]]
        self._cache, out, acc = self._spec_verify(
            self.params, self._cache, jnp.asarray(bt), jnp.asarray(ver),
            jnp.asarray(lens), jnp.asarray(k_eff),
        )
        self.metrics["decode_steps"] += 1
        self.metrics["spec_steps"] += 1
        out_np = np.asarray(out, np.int32)
        acc_np = np.asarray(acc, np.int32)
        for i in active:
            s = self.slots[i]
            self.metrics["spec_slot_steps"] += 1
            for t in out_np[i, : int(acc_np[i])]:
                t = int(t)
                s.generated.append(t)
                s.pos += 1  # this token's KV scattered back by the verify
                self.metrics["tokens"] += 1
                self.metrics["spec_accepted_tokens"] += 1
                send_delta(s.req.req_id, t, len(s.generated) - 1)
                if t == self.eos_id:
                    break  # accepted run truncates at eos; pages free below
            finish_if_done(i)

    # -- main loop ----------------------------------------------------------
    def run(
        self,
        request_consumer: StreamConsumer,
        response_producer: StreamProducer | None = None,
        *,
        max_requests: int | None = None,
        response_topic: str = "responses",
        stream_deltas: bool = True,
        close_responses: bool = True,
    ):
        """Serve until the request stream closes (or ``max_requests`` have
        been served) and all slots drain.  Re-entrant: a later ``run`` on a
        consumer that resumes the topic continues where this one stopped
        (the engine-restart path).

        No polling: while idle the loop sleeps on a condition variable the
        puller thread notifies; while decoding it never waits on the
        request stream at all.
        """
        pending: deque[Request] = deque()
        cond = threading.Condition()
        state = {
            "open": True, "pulled": 0, "error": None, "stop": False,
            "failed": [],  # (req_id, why) from the puller → rejected here
        }

        def want_more() -> bool:
            return max_requests is None or state["pulled"] < max_requests

        # Pull-side backpressure: resolve at most this many requests ahead
        # of admission (the seed engine's slots-bounded drain, kept) — a
        # 100k-deep request topic must not materialize 100k prompt arrays.
        high_water = 2 * len(self.slots)

        def pull_loop():
            # Blocks in the consumer (broker condition wait / connector
            # wait_for); the tick only makes stop/max_requests responsive.
            while True:
                with cond:
                    while (
                        not state["stop"]
                        and state["open"]
                        and want_more()
                        and len(pending) >= high_water
                    ):
                        cond.wait(_WAIT_TICK)  # admission drains → notify
                    if state["stop"] or not (state["open"] and want_more()):
                        return
                try:
                    proxy, meta = request_consumer.next_with_metadata(
                        timeout=_WAIT_TICK
                    )
                except StopIteration:
                    with cond:
                        state["open"] = False
                        cond.notify_all()
                    return
                except TimeoutError:
                    continue
                except BaseException as e:  # stream-level failure (broker,
                    # subscriber): fatal for the run, surfaced by run() —
                    # never a silently dead puller and a hung engine
                    with cond:
                        state["error"] = e
                        state["open"] = False
                        cond.notify_all()
                    return
                if proxy is None:
                    continue  # stray meta-only event: not a request
                # Per-request failures are NOT fatal: one tenant's evicted
                # payload or missing field must not abort everyone else's
                # generation.  Addressable bad requests become rejections;
                # unaddressable events (no req_id) can only be counted.
                req_id = None
                try:
                    req_id = meta["req_id"]
                    # metadata-only dispatch: the bulk prompt resolves
                    # here, in the engine — overlapped with the decode
                    # loop, never in an intermediate scheduler
                    body = extract(proxy)
                    f = object.__getattribute__(proxy, "__factory__")
                    if not getattr(f, "evict_on_resolve", True):
                        # persistent prompt bulk (producer without the
                        # one-shot contract): the request's lifetime takes
                        # custody so close() reclaims it
                        self._request_lifetime(req_id).add(
                            Store.get_or_reattach(f.store_name, f.connector),
                            f.key,
                        )
                    req = Request(
                        req_id=req_id,
                        prompt=np.asarray(body["prompt"], np.int32),
                        max_new_tokens=int(meta.get("max_new_tokens", 16)),
                    )
                except BaseException as e:
                    if req_id is None:
                        # unaddressable event: nobody else will ever pull
                        # this topic, so its unresolved bulk payload would
                        # be resident forever — reclaim it.  A failed
                        # reclaim is no longer swallowed: it is counted
                        # (``reclaim_failures``) and the orphan is handed
                        # to ProxySan so it surfaces in the leak report
                        # for as long as it stays resident.
                        f = None
                        try:
                            f = object.__getattribute__(proxy, "__factory__")
                            Store.get_or_reattach(
                                f.store_name, f.connector
                            ).evict(f.key)
                        except BaseException:
                            self.metrics["reclaim_failures"] += 1
                            if f is not None:
                                san = _sanitize.active_for(f.store_name)
                                if san is not None:
                                    san.note_orphan(
                                        f.store_name, f.connector, f.key
                                    )
                    with cond:
                        state["pulled"] += 1
                        if req_id is None:
                            self.metrics["malformed_events"] += 1
                        else:
                            state["failed"].append(
                                (req_id, f"bad request: {e!r}")
                            )
                        cond.notify_all()
                    continue
                with cond:
                    state["pulled"] += 1
                    pending.append(req)
                    self.metrics["max_pending"] = max(
                        self.metrics["max_pending"], len(pending)
                    )
                    cond.notify_all()

        puller = threading.Thread(target=pull_loop, daemon=True)
        puller.start()

        def send_done(req_id: str):
            if response_producer is None:
                return
            entry = self.completed[req_id]
            meta = {
                "req_id": req_id,
                "kind": "done",
                "n_tokens": len(entry["tokens"]),
            }
            if self.done_commit_prefix is not None:
                # fleet mode: commit the record at the deterministic key
                # shared by every engine that might finish this request
                # (put_if_absent — one payload no matter how many twins
                # complete a redispatched request); the event always
                # references that key, the router forwards the first one
                response_producer.send_committed(
                    response_topic,
                    {"req_id": req_id, **entry},
                    key=f"{self.done_commit_prefix}{req_id}",
                    metadata=meta,
                    lifetime=self._response_lifetime(req_id),
                )
                return
            response_producer.send(
                response_topic,
                {"req_id": req_id, **entry},
                metadata=meta,
                # the response lifetime takes custody of the completion
                # bulk: a client that never resolves it (crashed, filtered)
                # no longer leaks it past engine.close(); a client that
                # does resolve it evicts it first (one-shot contract)
                lifetime=self._response_lifetime(req_id),
            )
            response_producer.flush_topic(response_topic)

        def send_reject(req_id: str, why: str):
            self.rejected[req_id] = why
            if response_producer is not None:
                response_producer.send_meta(
                    response_topic,
                    {"req_id": req_id, "kind": "error", "error": why},
                )

        def send_delta(req_id: str, token: int, index: int):
            if stream_deltas and response_producer is not None:
                # incremental token delta: metadata-only, no store put — the
                # client's first token beats the full completion
                response_producer.send_meta(
                    response_topic,
                    {"req_id": req_id, "kind": "delta",
                     "token": token, "index": index},
                )

        def finish_if_done(slot_idx: int) -> bool:
            s = self.slots[slot_idx]
            last = s.generated[-1]
            done = (
                last == self.eos_id
                or len(s.generated) >= s.req.max_new_tokens
                or s.pos >= self.max_len - 1
            )
            if done:
                req_id = s.req.req_id
                self._finish(slot_idx)
                send_done(req_id)
            return done

        def pop_next(taken: set) -> tuple[str, Request | None, int, str]:
            """FIFO head-of-line admission decision for one request."""
            with cond:
                if not pending:
                    return ("empty", None, -1, "")
                req = pending[0]
                total = min(len(req.prompt) + req.max_new_tokens, self.max_len)
                if req.req_id in self.pages.live_sequences():
                    pending.popleft()  # one bad request must not crash
                    cond.notify_all()  # every other tenant's serve
                    return (
                        "reject", req, -1,
                        f"req_id {req.req_id!r} is already being served",
                    )
                if len(req.prompt) > self.max_len - 1:
                    pending.popleft()  # prompt alone overflows the cache
                    cond.notify_all()
                    return (
                        "reject", req, -1,
                        f"prompt of {len(req.prompt)} tokens exceeds "
                        f"max_len-1 ({self.max_len - 1})",
                    )
                if self.pages.pages_needed(total) > self.pages.num_pages:
                    pending.popleft()  # can never fit: reject, don't wedge
                    cond.notify_all()
                    return (
                        "reject", req, -1,
                        f"request needs {self.pages.pages_needed(total)} "
                        f"pages; the pool has {self.pages.num_pages}",
                    )
                if not self.pages.can_admit(total) or (
                    self.spec_k and not self.draft_pages.can_admit(total)
                ):
                    # backpressure: head-of-line waits for pages (FIFO —
                    # later requests must not starve an earlier one); under
                    # speculation BOTH pools must cover the full generation
                    self.metrics["queued_admissions"] += 1
                    return ("wait", None, -1, "")
                free = [
                    i for i, s in enumerate(self.slots)
                    if s.req is None and i not in taken
                ]
                if not free:
                    return ("wait", None, -1, "")
                pending.popleft()
                cond.notify_all()  # wake a pull blocked at high water
                return ("admit", req, free[0], "")

        def admit_pending() -> int:
            admitted = 0
            with cond:
                failed, state["failed"] = state["failed"], []
            for rid, why in failed:  # puller-detected per-request failures
                send_reject(rid, why)
            batching = self.paged and self.batch_prefill and self._can_batch
            while True:
                batch: list[tuple[Request, int]] = []
                taken: set[int] = set()
                while len(taken) < len(self.slots):
                    action, req, target, why = pop_next(taken)
                    if action == "reject":
                        send_reject(req.req_id, why)
                        continue
                    if action != "admit":
                        break
                    # allocate now (so can_admit sees this batch's pages);
                    # prefill + insert run once for the whole batch below
                    self._allocate_for(req)
                    batch.append((req, target))
                    taken.add(target)
                    if not batching:
                        break
                if not batch:
                    return admitted
                firsts = self._insert_prefill(batch)
                for (req, target), first in zip(batch, firsts):
                    send_delta(req.req_id, first, 0)
                    finish_if_done(target)  # 1-token request: done at admission
                    admitted += 1

        def serve_loop():
            while True:
                self.metrics["loop_iters"] += 1
                admit_pending()
                active = [
                    i for i, s in enumerate(self.slots) if s.req is not None
                ]
                if not active:
                    with cond:
                        if state["error"] is not None:
                            raise state["error"]
                        if not pending and not state["failed"]:
                            # every pulled request is resolved once pending
                            # is empty and no slot is active
                            if not state["open"] or not want_more():
                                return
                            # notification wait: woken by the puller on
                            # arrival or close; the tick bounds shutdown,
                            # not wake-up
                            self.metrics["idle_waits"] += 1
                            cond.wait(_WAIT_TICK)
                    continue
                if self.spec_k:
                    # speculative multi-token step: draft proposes, target
                    # verifies in one paged forward, accepted run streams out
                    self._spec_decode_step(active, send_delta, finish_if_done)
                    continue
                # batched decode step: every slot's last generated token is
                # fed back at that slot's own position (idle slots decode
                # garbage against the null page — never read)
                tokens = np.zeros((len(self.slots),), np.int32)
                lens = np.zeros((len(self.slots),), np.int32)
                for i in active:
                    s = self.slots[i]
                    tokens[i] = s.generated[-1]
                    lens[i] = s.pos
                self._ensure_cache()
                if self.paged:
                    # the page holding position pos must exist and be owned
                    # before the step writes it: extend — and any
                    # copy-on-write it triggers — happens pre-step
                    for i in active:
                        s = self.slots[i]
                        if self.pages.extend(s.req.req_id, s.pos + 1):
                            s.pages = self.pages.pages_of(s.req.req_id)
                    self._apply_cow()
                    width = self._bt_width(max(
                        self.pages.pages_needed(self.slots[i].pos + 1)
                        for i in active
                    ))
                    bt = np.full(
                        (len(self.slots), width), self._null_page, np.int32
                    )
                    for i in active:
                        s = self.slots[i]
                        cov = self.pages.pages_needed(s.pos + 1)
                        bt[i, :cov] = s.pages[:cov]
                    self._cache, logits = self._decode(
                        self.params, self._cache, jnp.asarray(bt),
                        jnp.asarray(tokens[:, None]), jnp.asarray(lens),
                    )
                else:
                    self._cache, logits = self._decode(
                        self.params, self._cache, jnp.asarray(tokens[:, None]),
                        jnp.asarray(lens),
                    )
                self.metrics["decode_steps"] += 1
                logits_np = np.asarray(logits, np.float32)
                for i in active:
                    s = self.slots[i]
                    nxt = int(np.argmax(logits_np[i, : self.cfg.vocab]))
                    s.generated.append(nxt)
                    s.pos += 1  # the fed-back token's KV is now cached
                    if not self.paged:
                        self.pages.extend(s.req.req_id, s.pos)
                    self.metrics["tokens"] += 1
                    send_delta(s.req.req_id, nxt, len(s.generated) - 1)
                    finish_if_done(i)

        try:
            serve_loop()
        finally:
            # Whatever exits the loop — drain, max_requests, or an
            # exception (decode failure, a response-store error) — the
            # puller must die with this run: an orphaned puller would keep
            # stealing requests into a dead run's pending deque forever.
            with cond:
                state["stop"] = True
                cond.notify_all()
            puller.join(timeout=5 * _WAIT_TICK)
        if response_producer is not None and close_responses:
            response_producer.close_topic(response_topic)
        return self.completed

    # -- lifecycle -----------------------------------------------------------
    def close(self, *, reclaim_responses: bool = True) -> None:
        """Tear the engine down and end every per-request scope.

        ``reclaim_responses=False`` is for the restart handoff: the
        response stream outlives this engine (``run(close_responses=
        False)`` or an engine replaced mid-stream), so completion bulks a
        lagging client has not resolved yet must stay resident — stream
        payloads resolve blocking, and evicting one under a live client
        wedges it.  Custody then rests with the clients' one-shot
        resolves (and ultimately whoever closes the topic).
        """
        for seq in self.pages.live_sequences():
            self.pages.free_sequence(seq)
        if self.spec_k:
            for seq in self.draft_pages.live_sequences():
                self.draft_pages.free_sequence(seq)
        self._live_prompts.clear()
        # Request-side scopes: persistent prompt bulks were consumed by
        # this engine's puller — always safe to reclaim.
        lifetimes, self._req_lifetimes = self._req_lifetimes, {}
        for lt in lifetimes.values():
            lt.close()
        # Response-side scopes: evict completion bulks no client resolved.
        # Default assumes the driver pattern (clients joined before close;
        # resolved one-shot payloads are already gone, the evict is then a
        # no-op), so in-flight resolves never race this.
        resp, self._resp_lifetimes = self._resp_lifetimes, {}
        if reclaim_responses:
            for lt in resp.values():
                lt.close()
        if self._owns_store:  # never close a store the caller handed in
            self.kv_store.close()
        if self._draft_store is not None:  # always engine-owned
            self._draft_store.close()
