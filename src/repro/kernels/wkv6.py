"""Pallas TPU kernel for the RWKV6 (WKV6) chunked recurrence.

The sequence is processed in chunks along a *sequential* grid dimension; the
per-(batch·head) recurrent state S (K×V, f32) lives in VMEM scratch and is
carried across chunk iterations — the TPU-native replacement for the CUDA
kernel's per-thread registers.  Within a chunk everything is parallel
matmul work for the MXU (intra-chunk scores (C×C), inter-chunk reads
against the carried state), with the log-space decay algebra of
models/rwkv.py::wkv6_chunked.

Layouts: r/k/lw (BH, S, K), v (BH, S, V), u (BH, K) (pre-broadcast per
head), out (BH, S, V).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref,  # (1, C, K)
    k_ref,  # (1, C, K)
    v_ref,  # (1, C, V)
    lw_ref,  # (1, C, K)
    u_ref,  # (1, K)
    o_ref,  # (1, C, V)
    state_scr,  # (K, V) f32
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (K,)
    s = state_scr[...]

    la = jnp.cumsum(lw, axis=0)  # (C, K) cumulative log decay
    lam = la - lw  # exclusive cumulative decay, ≤ 0
    # inter-chunk: o_t += (r_t * exp(lam_t)) @ S_prev
    o_inter = jax.lax.dot_general(
        r * jnp.exp(lam), s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # intra-chunk (strictly below diagonal): decay differences are masked
    # BEFORE exp (≤0 in the causal region → overflow-safe; the factored
    # exp(lam)·exp(-la) matmul form overflows once |la| ≳ 88).  This keeps
    # the (C,C,K) tile in VMEM on the VPU; the combine below is MXU work.
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (ti > si)[:, :, None]  # (C, C, 1)
    diff = lam[:, None, :] - la[None, :, :]  # (C, C, K) [t, s, k]
    pk = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)
    scores = jnp.einsum(
        "tk,sk,tsk->ts", r, k, pk, preferred_element_type=jnp.float32
    )
    o_intra = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # current-token bonus: (r_t · (u * k_t)) v_t
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)  # (C, 1)
    o_cur = bonus * v
    o_ref[0, :, :] = (o_inter + o_intra + o_cur).astype(o_ref.dtype)

    # state update: S' = S * exp(la_C) + Σ_s (k_s exp(la_C - la_s))ᵀ v_s
    laC = la[-1:, :]  # (1, K)
    k_dec = k * jnp.exp(laC - la)  # (C, K)
    state_scr[...] = s * jnp.exp(laC).T + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def wkv6_bh(
    r: jax.Array,  # (BH, S, K)
    k: jax.Array,
    v: jax.Array,  # (BH, S, V)
    lw: jax.Array,  # (BH, S, K)
    u: jax.Array,  # (BH, K)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, S, K = r.shape
    V = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, V), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, K), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, K), lambda bh, ci: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, V), v.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(r, k, v, lw, u)
