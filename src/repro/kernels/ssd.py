"""Pallas TPU kernel for the Mamba2 SSD chunked recurrence.

Same chunked-sequential pattern as wkv6.py but with *scalar* per-head decay:
the (P×N) state is carried in VMEM scratch across the sequential chunk grid
dimension; intra-chunk work is two MXU matmuls ((C×N)·(N×C) score tile and
(C×C)·(C×P) combine).

Layouts: x (BH, S, P), dt/la (BH, S), B/C (BH, S, N) (pre-broadcast per
head by ops.py), D (BH, 1), out (BH, S, P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, C, P)
    dt_ref,  # (1, C)
    la_ref,  # (1, C)
    b_ref,  # (1, C, N)
    c_ref,  # (1, C, N)
    d_ref,  # (1, 1)
    o_ref,  # (1, C, P)
    state_scr,  # (P, N) f32
    *,
    chunk: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)  # (C, P)
    dt = dt_ref[0].astype(jnp.float32)  # (C,)
    la_step = la_ref[0].astype(jnp.float32)  # (C,)
    Bm = b_ref[0].astype(jnp.float32)  # (C, N)
    Cm = c_ref[0].astype(jnp.float32)
    Dh = d_ref[0, 0].astype(jnp.float32)
    s = state_scr[...]  # (P, N)

    la = jnp.cumsum(la_step)  # (C,) inclusive cumulative log decay
    # inter-chunk: y_t += exp(la_t) · (C_t · s)
    cs = jax.lax.dot_general(
        Cm, s, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, P)
    y_inter = jnp.exp(la)[:, None] * cs
    # intra-chunk: y_t += Σ_{s≤t} exp(la_t - la_s) (C_t·B_s) Δ_s x_s
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C) [t, s]
    ti = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 1)
    causal = ti >= si
    # decay diff masked BEFORE exp (≤0 in causal region → overflow-safe)
    dec = jnp.exp(jnp.where(causal, la[:, None] - la[None, :], -jnp.inf))
    m = dec * cb * dt[None, :]
    y_intra = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, :, :] = (y_inter + y_intra + Dh * x).astype(o_ref.dtype)

    # state update: s' = s·exp(la_C) + Σ_s exp(la_C - la_s) Δ_s x_s ⊗ B_s
    laC = la[-1]
    w = jnp.exp(laC - la) * dt  # (C,)
    state_scr[...] = s * jnp.exp(laC) + jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def ssd_bh(
    x: jax.Array,  # (BH, S, P)
    dt: jax.Array,  # (BH, S)
    la: jax.Array,  # (BH, S) log decay per step
    Bm: jax.Array,  # (BH, S, N)
    Cm: jax.Array,  # (BH, S, N)
    D: jax.Array,  # (BH, 1)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    n = S // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, dt, la, Bm, Cm, D)
