"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the *semantic definitions*: naive, numerically-straightforward
implementations that the kernels must match (assert_allclose in
tests/test_kernels.py across shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, H, D)   (kv heads already expanded)
    v: jax.Array,  # (B, Sk, H, Dv)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    import math

    D = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] + (Sk - Sq) >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(v.dtype)


def wkv6_ref(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    lw: jax.Array,  # (B, S, H, K) log decay (≤0)
    u: jax.Array,  # (H, K)
    state: jax.Array | None = None,  # (B, H, K, V)
):
    """Step-by-step WKV6 recurrence (the paper's eq., O(S) sequential):
    o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t);  S_t = diag(w_t) S_{t-1} + k_tᵀv_t.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    s0 = state.astype(f32) if state is not None else jnp.zeros((B, H, K, V), f32)

    def step(s, xs):
        rt, kt, vt, lwt = (x.astype(f32) for x in xs)  # (B,H,K/V)
        kv = kt[..., None] * vt[..., None, :]  # (B,H,K,V)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, s + u.astype(f32)[None, :, :, None] * kv)
        s_new = jnp.exp(lwt)[..., None] * s + kv
        return s_new, ot

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, lw))
    sF, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3).astype(v.dtype), sF


def ssd_ref(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (≥0, already softplus'd)
    a_log: jax.Array,  # (B, S, H) log decay per step (≤0)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    D: jax.Array,  # (H,)
    state: jax.Array | None = None,  # (B, H, P, N)
):
    """Step-by-step SSD recurrence: h_t = a_t h_{t-1} + (Δ_t x_t)⊗B_t;
    y_t = C_t·h_t + D x_t."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    s0 = state.astype(f32) if state is not None else jnp.zeros((B, H, P, N), f32)

    def step(s, xs):
        xt, dtt, lat, Bt, Ct = xs
        xt, dtt, lat = xt.astype(f32), dtt.astype(f32), lat.astype(f32)
        s_new = s * jnp.exp(lat)[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, Bt.astype(f32)
        )
        yt = jnp.einsum("bn,bhpn->bhp", Ct.astype(f32), s_new)
        yt = yt + xt * D.astype(f32)[None, :, None]
        return s_new, yt

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        a_log.transpose(1, 0, 2),
        Bm.transpose(1, 0, 2),
        Cm.transpose(1, 0, 2),
    )
    sF, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3).astype(x.dtype), sF
