"""jit'd public wrappers around the Pallas kernels.

Each op reshapes model-layout tensors into kernel layout, dispatches to the
Pallas kernel on TPU (or ``interpret=True`` for CPU validation), and falls
back to the pure-jnp blockwise/chunked implementations otherwise.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bh
from repro.kernels.paged_attention import paged_attention_grouped
from repro.kernels.ssd import ssd_bh
from repro.kernels.wkv6 import wkv6_bh


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "impl", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    impl: str = "auto",  # auto | pallas | interpret | jnp
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Model-layout flash attention."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = H // Hkv
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        from repro.models.layers import blockwise_attention

        return blockwise_attention(q, k, v, causal=causal, q_offset=Sk - Sq)
    qbh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kbh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    vbh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, D)
    obh = flash_attention_bh(
        qbh, kbh, vbh,
        group=g, causal=causal, q_offset=Sk - Sq,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "interpret"),
    )
    return obh.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("impl",))
def paged_attention(
    q: jax.Array,  # (B, T, H, D) — T freshly written tokens per sequence
    k_pages: jax.Array,  # (P, page_size, Hkv, D) — the KV page pool
    v_pages: jax.Array,  # (P, page_size, Hkv, Dv)
    block_tables: jax.Array,  # (B, n) int32 physical page ids, token order
    lens: jax.Array,  # (B,) int32 valid tokens through each FIRST query
    *,
    impl: str = "auto",  # auto | pallas | interpret | jnp
) -> jax.Array:
    """Model-layout paged-attention decode over a block-table-indexed pool.

    T == 1 is the single-token decode step; T == k+1 is speculative
    decode's verify pass (query ``t`` attends keys ``< lens[b] + t``).
    """
    B, T, H, D = q.shape
    P, _, Hkv, Dv = v_pages.shape
    g = H // Hkv
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        from repro.models.layers import paged_decode_attention

        return paged_decode_attention(q, k_pages, v_pages, block_tables, lens)
    # queries-major row stacking: row t*g + lane matches the kernel's
    # ``t = row // group`` per-row causal mask
    qg = (
        q.reshape(B, T, Hkv, g, D)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, Hkv, T * g, D)
    )
    bt = jnp.clip(block_tables.astype(jnp.int32), 0, P - 1)  # DMA-safe padding
    obh = paged_attention_grouped(
        qg, k_pages, v_pages, bt, lens.astype(jnp.int32),
        num_queries=T,
        interpret=(impl == "interpret"),
    )
    return (
        obh.reshape(B, Hkv, T, g, Dv)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, T, H, Dv)
    )


@partial(jax.jit, static_argnames=("impl", "chunk"))
def wkv6(
    r: jax.Array,  # (B, S, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, S, H, V)
    lw: jax.Array,  # (B, S, H, K)
    u: jax.Array,  # (H, K)
    *,
    impl: str = "auto",
    chunk: int = 128,
) -> jax.Array:
    B, S, H, K = r.shape
    V = v.shape[-1]
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        from repro.models.rwkv import wkv6_chunked

        out, _ = wkv6_chunked(r, k, v, lw, u, chunk=chunk)
        return out
    tb = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, x.shape[-1])
    ubh = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    obh = wkv6_bh(
        tb(r), tb(k), tb(v), tb(lw), ubh,
        chunk=chunk, interpret=(impl == "interpret"),
    )
    return obh.reshape(B, H, S, V).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("impl", "chunk"))
def ssd(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    la: jax.Array,  # (B, S, H)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    D: jax.Array,  # (H,)
    *,
    impl: str = "auto",
    chunk: int = 128,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "jnp"
    if impl == "jnp":
        from repro.models.ssm import ssd_chunked

        out, _ = ssd_chunked(x, dt, la, Bm, Cm, D, chunk=chunk)
        return out
    xbh = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtbh = dt.transpose(0, 2, 1).reshape(B * H, S)
    labh = la.transpose(0, 2, 1).reshape(B * H, S)
    bbh = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    cbh = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    dbh = jnp.broadcast_to(D[None], (B, H)).reshape(B * H, 1)
    obh = ssd_bh(
        xbh, dtbh, labh, bbh, cbh, dbh,
        chunk=chunk, interpret=(impl == "interpret"),
    )
    return obh.reshape(B, H, S, P).transpose(0, 2, 1, 3)
