"""Pallas TPU kernels for the model zoo's compute hot spots.

- flash_attention: GQA causal flash attention (dense/MoE/VLM families)
- wkv6: RWKV6 chunked data-dependent-decay recurrence
- ssd: Mamba2 state-space-dual chunked recurrence

Each has a pure-jnp oracle in ref.py and a jit'd dispatch wrapper in ops.py
(pallas on TPU, interpret=True for CPU validation, jnp fallback).

Kernels are written against the current Pallas API spelling
(``pltpu.CompilerParams``); _compat aliases the old name before any kernel
module loads.
"""
from repro._compat.jaxshims import ensure_pallas_compat

ensure_pallas_compat()

from repro.kernels import ops, ref  # noqa: E402
