"""Pallas TPU paged-attention decode kernel (T ≥ 1 query tokens, GQA).

The serving engine stores KV in a page pool ``(P, page_size, Hkv, D)``; a
sequence's cache is the ordered list of physical pages its ``PageTable``
block table names.  This kernel attends a small block of freshly written
query tokens per sequence directly against that pool: the block table is a
**scalar-prefetched** operand, so each grid step's BlockSpec index_map reads
``bt[b, i]`` and the page gather *is* the DMA schedule — no dense
``(B, max_len, ...)`` cache is ever materialized, and sequences pay for the
pages they occupy, not for ``max_len``.

The query block covers speculative decode's verify pass: T = k+1 positions
per sequence attend in ONE kernel launch.  Queries stack into the row axis
as ``(T*G, D)`` — row ``r`` is query ``t = r // G``, head-group lane
``g = r % G`` — so the single-token layout (T == 1) is the degenerate case
and compiles to exactly the previous kernel.

Layout: q ``(B, Hkv, T*G, D)`` (T tokens per sequence, q heads grouped by
their kv head, queries-major), k/v pages ``(P, page_size, Hkv, D)``, block
tables ``(B, n)`` int32, lens ``(B,)`` int32 — ``lens[b]`` counts valid
tokens through the FIRST query's own position, so query ``t`` attends
``pos < lens[b] + t``.  Grid ``(B, Hkv, n)``: the page axis is sequential,
so the online-softmax stats (m, l, acc) live in VMEM scratch that persists
across pages — same accumulator discipline as flash_attention.  Pages at or
beyond every query's reach are skipped with ``pl.when`` (their DMA still
lands on a valid page — callers pad short block-table rows with any
in-range page id).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    bt_ref,  # (B, n) int32 scalar-prefetch: the block tables
    lens_ref,  # (B,) int32 scalar-prefetch: valid tokens per sequence
    q_ref,  # (1, 1, T*G, D)
    k_ref,  # (1, page_size, 1, D)
    v_ref,  # (1, page_size, 1, Dv)
    o_ref,  # (1, 1, T*G, Dv)
    m_scr,  # (T*G, 1) f32
    l_scr,  # (T*G, 1) f32
    acc_scr,  # (T*G, Dv) f32
    *,
    scale: float,
    page_size: int,
    num_page_slots: int,
    group: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = lens_ref[b]
    num_queries = q_ref.shape[2] // group

    # page entirely past even the LAST query's reach: skip
    @pl.when(i * page_size < seq_len + num_queries - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (T*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page_size, D)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (page_size, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (T*G, page_size)
        pos = i * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # row r is query t = r // group: it attends pos < seq_len + t
        t_row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        s = jnp.where(pos < seq_len + t_row, s, NEG_INF)
        m_prev = m_scr[...]  # (T*G, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(i == num_page_slots - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)  # lens == 0 → well-defined zeros
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention_grouped(
    q: jax.Array,  # (B, Hkv, T*G, D) — queries-major row stacking
    k_pages: jax.Array,  # (P, page_size, Hkv, D)
    v_pages: jax.Array,  # (P, page_size, Hkv, Dv)
    block_tables: jax.Array,  # (B, n) int32 physical page ids, in token order
    lens: jax.Array,  # (B,) int32 — valid tokens through the first query
    *,
    num_queries: int = 1,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, QG, D = q.shape
    P, page_size, _, Dv = v_pages.shape
    n = block_tables.shape[1]
    if QG % num_queries:
        raise ValueError(f"query rows {QG} not divisible by T={num_queries}")
    G = QG // num_queries
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _paged_kernel,
        scale=scale,
        page_size=page_size,
        num_page_slots=n,
        group=G,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (block_tables, lens) usable in index_maps
        grid=(B, Hkv, n),
        in_specs=[
            pl.BlockSpec((1, 1, QG, D), lambda b, h, i, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec(
                (1, page_size, 1, D), lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0)
            ),
            pl.BlockSpec(
                (1, page_size, 1, Dv), lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, QG, Dv), lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((QG, 1), jnp.float32),
            pltpu.VMEM((QG, 1), jnp.float32),
            pltpu.VMEM((QG, Dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, QG, Dv), v_pages.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(block_tables, lens, q, k_pages, v_pages)
