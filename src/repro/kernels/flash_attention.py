"""Pallas TPU flash attention (GQA-aware, causal) — the perf-critical
attention hot spot for the dense/MoE/VLM families.

TPU adaptation (vs. the CUDA flash-attention algorithm): tiling is chosen
for VMEM residency and MXU alignment — block_q × head_dim and
block_k × head_dim tiles live in VMEM, the (block_q × block_k) score tile
feeds the 128×128 MXU, and the online-softmax running stats (m, l, acc) sit
in VMEM scratch that persists across the sequential kv grid dimension
(TPU grids are sequential, so no atomics / split-k reduction are needed —
the scratch *is* the accumulator).  Causal blocks entirely above the
diagonal are skipped with ``pl.when`` predication.

Layout: q (BH, Sq, D), k/v (BHkv, Sk, D) — the wrapper (ops.py) folds
batch×heads and maps each q-head group to its kv head via the BlockSpec
index_map (no materialized KV repetition).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, block_q, D)
    k_ref,  # (1, block_k, D)
    v_ref,  # (1, block_k, D)
    o_ref,  # (1, block_q, D)
    m_scr,  # (block_q, 1) f32
    l_scr,  # (block_q, 1) f32
    acc_scr,  # (block_q, D) f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    causal: bool,
    num_k_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]  # (block_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # (block_q, 1)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        # skip kv blocks entirely above the causal diagonal of this q block
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bh(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BHkv, Sk, D)
    v: jax.Array,  # (BHkv, Sk, D)
    *,
    group: int,  # q heads per kv head (BH = BHkv * group)
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    BHkv, Sk, _ = k.shape
    assert BH == BHkv * group, (BH, BHkv, group)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        num_k_blocks=nk,
        q_offset=q_offset,
    )
    grid = (BH, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
