"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048 32H d_ff=8192 vocab=32000, ssm_state=64. [arXiv:2411.15242]
One shared attention+MLP block is reused every ``shared_attn_every`` mamba
layers (per-invocation LoRA omitted — see DESIGN.md §7).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    shared_attn_every=6,
)

SMOKE_CONFIG = ArchConfig(
    name="zamba2-1.2b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_conv=4,
    shared_attn_every=2,
)
