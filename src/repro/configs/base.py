"""Unified architecture configuration for the assigned model pool."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | mla_moe | encdec | rwkv | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # common
    head_dim: int = 0  # 0 → d_model // n_heads
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0  # stablelm uses 0.25
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256  # Megatron-style padding for TP divisibility

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0  # leading dense layers (deepseek-v3: 3)
    router_aux_weight: float = 0.001

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    use_mtp: bool = False  # multi-token prediction head (depth 1)

    # M-RoPE (qwen2-vl)
    use_mrope: bool = False
    mrope_sections: tuple[int, ...] = ()
    vision_embeds: int = 0  # stub frontend: number of precomputed patch embeds

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 0  # stub frontend: precomputed frame embeddings

    # RWKV6
    rwkv_head_dim: int = 64

    # Mamba2 / Zamba2 hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    shared_attn_every: int = 0  # zamba2: shared block cadence

    # training
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots (activation-checkpoint policy)
    scan_layers: bool = True
    # beyond-paper perf levers (default off = paper-faithful baseline)
    attn_causal_skip: bool = False  # skip fully-masked KV chunks (≈½ attn FLOPs)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, self.vocab_pad_multiple)

    @property
    def attn_free(self) -> bool:
        return self.family == "rwkv"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear-attention)."""
        return self.family in ("rwkv", "hybrid")

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs; else the recorded skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k needs sub-quadratic attention (skip per assignment)"
    return True, ""
