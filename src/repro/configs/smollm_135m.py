"""smollm-135m [dense] — llama-arch small. 30L d_model=576 9H (GQA kv=3)
d_ff=1536 vocab=49152. [hf:HuggingFaceTB/SmolLM-135M]

Note: 9 heads / 3 kv heads are indivisible by the 16-way model axis — the
shape-aware sharding rules replicate head dims and keep TP on mlp/vocab.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49_152,
    tie_embeddings=True,
)

SMOKE_CONFIG = ArchConfig(
    name="smollm-135m-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
)
