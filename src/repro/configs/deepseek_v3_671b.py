"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MoE 256e top-8.
[arXiv:2412.19437; hf]  First 3 layers dense (d_ff 18432, per the HF config);
the assigned d_ff=2048 is the routed-expert intermediate size.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense-layer FFN (first_k_dense layers)
    vocab=129_280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    use_mtp=True,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek-v3-671b-smoke",
    family="mla_moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    moe_d_ff=32,
    first_k_dense=1,
    use_mla=True,
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    use_mtp=True,
)
