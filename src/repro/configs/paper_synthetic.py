"""paper-synthetic — tiny dense LM used by the paper-pattern examples and
benchmarks (the paper itself has no model; this exercises the framework's
own end-to-end path at ~100M scale for the quickstart driver)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paper-synthetic",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=32_000,
    tie_embeddings=True,
)

SMOKE_CONFIG = ArchConfig(
    name="paper-synthetic-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
)
