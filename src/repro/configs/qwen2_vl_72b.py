"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (vision frontend STUB).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064. [arXiv:2409.12191]
input_specs() provides precomputed patch embeddings; M-RoPE uses
(temporal, height, width) position ids with sections (16, 24, 24) over the
128-dim rotary half (matching the HF config's mrope_section).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab=152_064,
    rope_theta=1_000_000.0,
    use_mrope=True,
    mrope_sections=(16, 24, 24),
    vision_embeds=256,  # stub: 256 precomputed patch embeddings per sample
)

SMOKE_CONFIG = ArchConfig(
    name="qwen2-vl-72b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    use_mrope=True,
    mrope_sections=(2, 3, 3),
    vision_embeds=8,
)
