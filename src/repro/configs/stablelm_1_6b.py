"""stablelm-1.6b [dense] — 24L d_model=2048 32H d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b]  Partial rotary (25%) + LayerNorm."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100_352,
    rotary_pct=0.25,
    norm="layernorm",
)

SMOKE_CONFIG = ArchConfig(
    name="stablelm-1.6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    rotary_pct=0.25,
    norm="layernorm",
)
