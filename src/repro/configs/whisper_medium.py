"""whisper-medium [audio] — enc-dec, conv frontend STUB.

24L (decoder; +24L encoder) d_model=1024 16H d_ff=4096 vocab=51865.
[arXiv:2212.04356]  input_specs() provides precomputed frame embeddings
(B, 1500, d_model) per the assignment's stub-frontend rule.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51_865,
    encoder_layers=24,
    encoder_frames=1500,
    norm="layernorm",
    rotary_pct=0.0,  # whisper uses learned/sinusoidal positions, not rope
)

SMOKE_CONFIG = ArchConfig(
    name="whisper-medium-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    encoder_layers=2,
    encoder_frames=32,
    norm="layernorm",
    rotary_pct=0.0,
)
