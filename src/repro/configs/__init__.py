"""Assigned architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs.base import SHAPES, ArchConfig, ShapeCell, cell_applicable


def _import_all():
    from repro.configs import (  # noqa: F401
        deepseek_7b,
        deepseek_v3_671b,
        granite_8b,
        granite_moe_1b_a400m,
        paper_synthetic,
        qwen2_vl_72b,
        rwkv6_7b,
        smollm_135m,
        stablelm_1_6b,
        whisper_medium,
        zamba2_1_2b,
    )

    mods = [
        deepseek_v3_671b,
        granite_moe_1b_a400m,
        whisper_medium,
        qwen2_vl_72b,
        rwkv6_7b,
        granite_8b,
        smollm_135m,
        stablelm_1_6b,
        deepseek_7b,
        zamba2_1_2b,
        paper_synthetic,
    ]
    return {m.CONFIG.name: m for m in mods}


_REGISTRY: dict | None = None


def registry() -> dict:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _import_all()
    return _REGISTRY


def get_config(name: str) -> ArchConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(reg)}")
    return reg[name].CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    return registry()[name].SMOKE_CONFIG


def arch_names(include_synthetic: bool = False) -> list[str]:
    names = [n for n in registry() if n != "paper-synthetic"]
    if include_synthetic:
        names.append("paper-synthetic")
    return names


__all__ = [
    "ArchConfig",
    "SHAPES",
    "ShapeCell",
    "arch_names",
    "cell_applicable",
    "get_config",
    "get_smoke_config",
]
