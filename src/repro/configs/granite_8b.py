"""granite-8b [dense] — llama-arch, code. 36L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=49152. [arXiv:2405.04324]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab=49_152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = ArchConfig(
    name="granite-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    tie_embeddings=True,
)
