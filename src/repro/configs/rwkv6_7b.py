"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=4096 d_ff=14336 vocab=65536. [arXiv:2404.05892]
64 WKV heads of dim 64; decode state is O(1) per layer.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="rwkv",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14_336,
    vocab=65_536,
    rwkv_head_dim=64,
)

SMOKE_CONFIG = ArchConfig(
    name="rwkv6-7b-smoke",
    family="rwkv",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    rwkv_head_dim=16,
)
