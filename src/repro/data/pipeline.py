"""Input pipeline built on the paper's streaming + futures patterns.

A producer thread (or pool) tokenizes/assembles batches and publishes them
through a :class:`StreamProducer` — *metadata* to the broker, *bulk* to the
Store.  The trainer iterates a :class:`StreamConsumer`, receiving proxies;
the host→device transfer happens only at ``resolve`` time, and a prefetch
depth of N keeps the next batches' bulk fetch overlapped with the current
step's compute (the paper's Fig 3 pipelining, applied to input feeding).

The dispatcher position of the paper's Fig 4 corresponds to the trainer's
control loop: it only ever sees metadata (step id, shapes) until the step
function actually consumes the tensors.
"""
from __future__ import annotations

import threading
from typing import Callable, Iterator

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.proxy import Proxy, extract
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.models.api import synth_batch


class SyntheticCorpus:
    """Deterministic synthetic LM corpus (zipfian tokens with local structure
    so loss can actually fall): batch factory for the quickstart driver."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.rng = np.random.default_rng(seed)
        # fixed random bigram table → learnable structure
        self.K = 64
        self.table = self.rng.integers(0, cfg.vocab, (cfg.vocab % 4096 + 4096, self.K))

    def next_batch(self, step: int) -> dict:
        B, S, V = self.batch, self.seq, self.cfg.vocab
        r = np.random.default_rng(step)
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = r.integers(0, V, B)
        T = self.table.shape[0]
        for t in range(S):
            nxt = self.table[toks[:, t] % T, r.integers(0, self.K, B)]
            toks[:, t + 1] = nxt % V
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.cfg.family == "encdec":
            batch["frames"] = r.normal(
                size=(B, self.cfg.encoder_frames, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.use_mrope:
            p = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
            batch["positions"] = np.stack([p, p, p]).astype(np.int32)
        if self.cfg.vision_embeds:
            batch["vision_embeds"] = r.normal(
                size=(B, self.cfg.vision_embeds, self.cfg.d_model)
            ).astype(np.float32)
            batch["labels"][:, : self.cfg.vision_embeds] = -1
        return batch


class StreamingDataLoader:
    """ProxyStream-backed loader: producer thread → broker+store → proxies."""

    def __init__(
        self,
        batch_factory: Callable[[int], dict],
        *,
        store: Store | None = None,
        num_steps: int | None = None,
        prefetch: int = 2,
        topic: str = "train",
    ):
        self.batch_factory = batch_factory
        self.store = store or Store(f"data-{id(self)}")
        self.topic = topic
        self.num_steps = num_steps
        self.prefetch = prefetch
        ns = f"pipe-{id(self)}"
        self._producer = StreamProducer(
            QueuePublisher(ns), {topic: self.store}, evict_on_resolve=True
        )
        self._subscriber = QueueSubscriber(topic, ns)
        self._consumer = StreamConsumer(self._subscriber, timeout=120.0)
        self._sem = threading.Semaphore(prefetch)  # bounded buffer
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._started = False
        self._stop = threading.Event()

    def _produce(self):
        step = 0
        while not self._stop.is_set():
            if self.num_steps is not None and step >= self.num_steps:
                break
            self._sem.acquire()
            batch = self.batch_factory(step)
            self._producer.send(self.topic, batch, metadata={"step": step})
            self._producer.flush_topic(self.topic)
            step += 1
        self._producer.close_topic(self.topic)

    def __iter__(self) -> Iterator[Proxy]:
        if not self._started:
            self._started = True
            self._thread.start()
        for proxy in self._consumer:
            self._sem.release()  # producer may run ahead again
            yield proxy

    def stop(self):
        self._stop.set()
        self._sem.release()

    def metrics(self) -> dict:
        return self.store.metrics.snapshot()
