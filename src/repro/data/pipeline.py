"""Input pipeline built on the paper's streaming + futures patterns.

A producer thread (or pool) tokenizes/assembles batches and publishes them
through a :class:`StreamProducer` — *metadata* to the broker, *bulk* to the
Store.  The trainer iterates a :class:`StreamConsumer`, receiving proxies;
the host→device transfer happens only at ``resolve`` time, and a prefetch
depth of N keeps the next batches' bulk fetch overlapped with the current
step's compute (the paper's Fig 3 pipelining, applied to input feeding).

The dispatcher position of the paper's Fig 4 corresponds to the trainer's
control loop: it only ever sees metadata (step id, shapes) until the step
function actually consumes the tensors.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.proxy import Proxy, extract
from repro.core.store import Store
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)
from repro.dist.fault import StragglerPolicy
from repro.models.api import synth_batch


class SyntheticCorpus:
    """Deterministic synthetic LM corpus (zipfian tokens with local structure
    so loss can actually fall): batch factory for the quickstart driver."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.rng = np.random.default_rng(seed)
        # fixed random bigram table → learnable structure
        self.K = 64
        self.table = self.rng.integers(0, cfg.vocab, (cfg.vocab % 4096 + 4096, self.K))

    def next_batch(self, step: int) -> dict:
        B, S, V = self.batch, self.seq, self.cfg.vocab
        r = np.random.default_rng(step)
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = r.integers(0, V, B)
        T = self.table.shape[0]
        for t in range(S):
            nxt = self.table[toks[:, t] % T, r.integers(0, self.K, B)]
            toks[:, t + 1] = nxt % V
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
        if self.cfg.family == "encdec":
            batch["frames"] = r.normal(
                size=(B, self.cfg.encoder_frames, self.cfg.d_model)
            ).astype(np.float32)
        if self.cfg.use_mrope:
            p = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
            batch["positions"] = np.stack([p, p, p]).astype(np.int32)
        if self.cfg.vision_embeds:
            batch["vision_embeds"] = r.normal(
                size=(B, self.cfg.vision_embeds, self.cfg.d_model)
            ).astype(np.float32)
            batch["labels"][:, : self.cfg.vision_embeds] = -1
        return batch


class StreamingDataLoader:
    """ProxyStream-backed loader: producer thread → broker+store → proxies."""

    def __init__(
        self,
        batch_factory: Callable[[int], dict],
        *,
        store: Store | None = None,
        num_steps: int | None = None,
        prefetch: int = 2,
        topic: str = "train",
    ):
        self.batch_factory = batch_factory
        self.store = store or Store(f"data-{id(self)}")
        self.topic = topic
        self.num_steps = num_steps
        self.prefetch = prefetch
        ns = f"pipe-{id(self)}"
        self._producer = StreamProducer(
            QueuePublisher(ns), {topic: self.store}, evict_on_resolve=True
        )
        self._subscriber = QueueSubscriber(topic, ns)
        self._consumer = StreamConsumer(self._subscriber, timeout=120.0)
        self._sem = threading.Semaphore(prefetch)  # bounded buffer
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._started = False
        self._stop = threading.Event()

    def _produce(self):
        step = 0
        while not self._stop.is_set():
            if self.num_steps is not None and step >= self.num_steps:
                break
            self._sem.acquire()
            batch = self.batch_factory(step)
            self._producer.send(self.topic, batch, metadata={"step": step})
            self._producer.flush_topic(self.topic)
            step += 1
        self._producer.close_topic(self.topic)

    def __iter__(self) -> Iterator[Proxy]:
        if not self._started:
            self._started = True
            self._thread.start()
        for proxy in self._consumer:
            self._sem.release()  # producer may run ahead again
            yield proxy

    def stop(self):
        self._stop.set()
        self._sem.release()

    def metrics(self) -> dict:
        return self.store.metrics.snapshot()


# ---------------------------------------------------------------------------
# Shard dispatch with redispatch (the multi-host fault path's data plane)
# ---------------------------------------------------------------------------


@dataclass
class _Assignment:
    """One in-flight shard: who has it, since when, how many issues."""

    step: int
    worker: str
    issued: float
    attempts: int = 1
    history: list[str] = field(default_factory=list)


class DispatchingDataLoader:
    """Shard-dispatching loader: shards are *assigned* to named workers and
    re-issued when a worker straggles or dies.

    This is the PR 1 ``StragglerPolicy`` acted on instead of recorded (the
    ROADMAP's "redispatch wiring into the data loader"):

    - a dispatcher assigns shard ``step`` to a live worker (round-robin,
      liveness from an optional lease ``monitor``);
    - workers publish each shard with an atomic ``Store.put_if_absent``
      keyed by step, so a re-issued shard computed twice commits exactly
      once (the connector arbitrates, same protocol as ``ProxyFuture.
      set_result``);
    - a supervisor grades every in-flight shard's elapsed time with
      ``StragglerPolicy.grade`` (non-recording — partial durations must not
      poison the trailing median) and re-issues on a ``"redispatch"`` grade
      or a dead worker, preferring a *different* live worker;
    - the consumer iterates steps in order, blocking on the connector's
      notification-based ``wait_for``, and yields one-shot proxies
      (``evict_on_resolve`` — a consumed shard's payload is reclaimed).

    Workers here are threads with an injectable ``worker_fn`` (tests hang
    one to force a redispatch); on a real deployment each worker loop runs
    in its own process against the same connector — the commit protocol is
    already cross-process.
    """

    def __init__(
        self,
        batch_factory: Callable[[int], dict],
        *,
        num_steps: int,
        store: Store | None = None,
        workers: int | list[str] = 2,
        policy: StragglerPolicy | None = None,
        monitor=None,
        prefetch: int = 2,
        shard_timeout: float = 120.0,
        worker_fn: Callable[[str, int], dict] | None = None,
        supervise_every: float = 0.02,
    ):
        self.batch_factory = batch_factory
        self.num_steps = num_steps
        self.store = store or Store(f"dispatch-{id(self)}")
        self.policy = policy or StragglerPolicy()
        self.monitor = monitor
        self.prefetch = prefetch
        self.shard_timeout = shard_timeout
        self.worker_fn = worker_fn or (lambda w, step: self.batch_factory(step))
        self.supervise_every = supervise_every
        self.workers = (
            [f"dw{i}" for i in range(workers)]
            if isinstance(workers, int)
            else list(workers)
        )
        self.redispatches: list[dict] = []  # (step, from, to, reason) records
        self.errors: list[dict] = []  # worker-side exceptions (step, worker, error)
        self._ns = f"shard-{id(self)}"
        self._queues: dict[str, queue.Queue] = {w: queue.Queue() for w in self.workers}
        self._inflight: dict[int, _Assignment] = {}
        self._done: set[int] = set()  # worker-side commit acknowledgements
        self._failed: set[int] = set()  # steps whose current issue errored
        self._lock = threading.Lock()
        self._sem = threading.Semaphore(prefetch)
        self._stop = threading.Event()
        self._rr = 0
        self._started = False
        self._threads: list[threading.Thread] = []

    def _shard_key(self, step: int) -> str:
        return f"{self._ns}-s{step}"

    # -- membership -------------------------------------------------------------
    def _live_workers(self) -> list[str]:
        if self.monitor is None:
            return self.workers
        live_fn = getattr(self.monitor, "live_workers", None) or getattr(
            self.monitor, "live"
        )
        live = set(live_fn())
        return [w for w in self.workers if w in live]

    def _pick_worker(
        self, *, exclude: str | None = None, live: list[str] | None = None
    ) -> str | None:
        live = self._live_workers() if live is None else live
        if not live:
            return None
        pool = [w for w in live if w != exclude] or live
        self._rr += 1
        return pool[self._rr % len(pool)]

    # -- worker / dispatcher / supervisor loops -----------------------------------
    def _worker_loop(self, name: str):
        q = self._queues[name]
        while not self._stop.is_set():
            step = q.get()
            if step is None:
                return
            try:
                batch = self.worker_fn(name, step)
                with self._lock:
                    if step in self._done:
                        # a redispatched twin already committed AND the
                        # consumer may have evicted the key — publishing now
                        # would leak an orphaned payload nobody reads
                        continue
                # exactly-once commit: a redispatched shard may be computed
                # by two workers; the connector lets exactly one win
                self.store.put_if_absent(batch, self._shard_key(step))
                with self._lock:
                    self._done.add(step)
            except Exception as e:  # noqa: BLE001 - the worker must survive
                # a dead worker thread would strand every step queued to it;
                # record the error and flag the step for immediate re-issue
                with self._lock:
                    self._failed.add(step)
                self.errors.append(
                    {"step": step, "worker": name, "error": repr(e)}
                )

    def _dispatch_loop(self):
        for step in range(self.num_steps):
            if self._stop.is_set():
                return
            self._sem.acquire()
            worker = None
            while worker is None and not self._stop.is_set():
                worker = self._pick_worker()
                if worker is None:
                    # documented startup backoff: no live workers yet
                    time.sleep(self.supervise_every)  # proxylint: disable=no-sleep-poll
            if worker is None:
                return
            with self._lock:
                self._inflight[step] = _Assignment(step, worker, time.perf_counter())
            self._queues[worker].put(step)

    def _supervise_loop(self):
        while not self._stop.is_set():
            # the supervise tick IS the loop cadence (timeout scan), not a
            # poll for events
            time.sleep(self.supervise_every)  # proxylint: disable=no-sleep-poll
            now = time.perf_counter()
            with self._lock:
                inflight = list(self._inflight.values())
                done = set(self._done)
                failed = set(self._failed)
            # one membership read per tick, not per assignment: a
            # lease-backed monitor answers from the channel (file stats /
            # shm opens), and liveness cannot change within a tick anyway
            live = self._live_workers()
            for a in inflight:
                if a.step in done:
                    # completed: its duration feeds the trailing median
                    self.policy.observe(now - a.issued)
                    with self._lock:
                        self._inflight.pop(a.step, None)
                    continue
                dead = self.monitor is not None and a.worker not in live
                errored = a.step in failed
                grade = self.policy.grade(now - a.issued)
                if not dead and not errored and grade != "redispatch":
                    continue
                target = self._pick_worker(exclude=a.worker, live=live)
                if target is None:
                    continue  # nobody to re-issue to; keep waiting
                reason = (
                    "worker-error" if errored
                    else "dead-worker" if dead
                    else "straggler"
                )
                self.redispatches.append(
                    {"step": a.step, "from": a.worker, "to": target,
                     "reason": reason, "attempt": a.attempts + 1}
                )
                with self._lock:
                    a.history.append(a.worker)
                    a.worker = target
                    a.attempts += 1
                    a.issued = now  # grade the new issue, not the stuck one
                    self._failed.discard(a.step)  # the re-issue gets a clean slate
                self._queues[target].put(a.step)

    # -- consumer ---------------------------------------------------------------
    def start(self) -> None:
        """Launch worker/dispatcher/supervisor threads (idempotent;
        ``__iter__`` calls it, tests call it early to stage failures)."""
        if self._started:
            return
        self._started = True
        self._threads = [
            threading.Thread(target=self._worker_loop, args=(w,), daemon=True)
            for w in self.workers
        ]
        self._threads.append(
            threading.Thread(target=self._dispatch_loop, daemon=True)
        )
        self._threads.append(
            threading.Thread(target=self._supervise_loop, daemon=True)
        )
        for t in self._threads:
            t.start()

    def __iter__(self) -> Iterator[Proxy]:
        self.start()
        for step in range(self.num_steps):
            try:
                self.store.wait_for(
                    self._shard_key(step), timeout=self.shard_timeout
                )
            except TimeoutError as e:
                if self.errors:  # surface the root cause, not a bare timeout
                    raise RuntimeError(
                        f"shard {step} never committed within "
                        f"{self.shard_timeout}s; worker errors: {self.errors}"
                    ) from e
                raise
            self._sem.release()  # dispatcher may run ahead again
            yield self.store.proxy_from_key(
                self._shard_key(step), evict_on_resolve=True
            )

    def stop(self):
        self._stop.set()
        for q in self._queues.values():
            q.put(None)
        self._sem.release()

    def metrics(self) -> dict:
        return self.store.metrics.snapshot()
