"""BENCH_serve — serving hot-path trajectory artifact.

Companion to BENCH_proxy/BENCH_stream: one machine-readable JSON per PR
generation capturing the serving claims this repo gates
(``scripts/check.sh`` + ``scripts/compare_bench.py --serve``).

This box is CPU-share throttled, so every gated metric is a *same-run
ratio* (both sides measured back-to-back on the same engine, so load
cancels — the trick the proxy/stream gates use).  Absolute rates are
recorded with an ``info_`` prefix, reported but never gated.

Gated metrics:

- ``ttft_speedup``             — full-completion latency over streamed
  time-to-first-token for multi-token requests (one warmed engine, deltas
  observed by a real ServeClient on the response topic).  The streaming
  claim: a client sees its first token a prefill after admission, not a
  whole generation later.  TTFT is a few-ms latency floor read across a
  thread boundary, so the gate takes the best of ``TTFT_ROUNDS`` rounds
  (latency floors are load-stable, like the stream gate's wake latency)
  and saturates at ``TTFT_CAP`` (like the proxy gate's ratio cap).
- ``continuous_vs_static_ratio`` — wall time of static batching (admit a
  full batch, drain it completely, only then admit the next) over
  continuous batching (slots refill as sequences finish) for the same
  mixed-length workload on the same engine.
- ``slot_scaling_ratio``       — tokens/s with all slots decoding
  concurrently over tokens/s serving the same requests one at a time.
  The batched decode step's cost is ~flat in active-slot count, so
  continuous batching multiplies throughput; a regression here means the
  per-slot work stopped being batched.
- ``paged_vs_dense_decode_ratio`` — tokens/s of the paged-pool decode
  over the dense (L, B, max_len) layout on a long-context engine
  (same params, same workload, back-to-back).  The paged step gathers
  only the pages a sequence occupies; the dense step attends over the
  whole max_len cache — the paging claim, measured.
- ``batched_prefill_speedup``  — wall time admitting a slots-sized
  backlog one prefill at a time over admitting it as ONE padded prefill
  + one multi-page insert (same engine, both paths warm).
- ``prefix_pages_saved_ratio`` — fresh pages allocated WITHOUT prefix
  sharing over fresh pages WITH it, for a workload of prompts sharing a
  64-token system prefix.  Deterministic page arithmetic (refcounted
  aliasing through the ownership store), no timers involved.
- ``fleet_scaling``             — aggregate client-observed tokens/s of
  a TWO-engine fleet (subprocess engines behind ``serve.router``) over a
  ONE-engine fleet, same workload back-to-back.  This box is a single
  CPU share, so two processes cannot beat one in wall clock; the claim
  the ratio gates is that the router fan-out hop costs ~nothing — the
  fleet serves at the single-engine rate (a router that serialized
  forwarding, resolved proxies, or sat in the delta hot path would
  collapse it).  Absolute rates, assignment balance, and the fleet p99
  TTFT ride along as info.
- ``spec_accepted_tokens_per_step`` — speculative decode's accepted
  tokens per slot-step with a self-draft (deterministic counter
  arithmetic off the engine's metrics, no timers).  The self-draft
  ceiling is spec_k+1; a broken draft/verify path collapses the rate to
  exactly 1.0 (every step accepts only the corrected token), far below
  the committed baseline.  ``check.sh`` passes ``--require`` for this
  metric so it cannot silently vanish from the bench.

Full runs repeat the suite three times and commit the element-wise median
(``BENCH_serve.json``); ``--quick`` runs once into
``BENCH_serve.quick.json`` for the CI gate.  ``--quick`` skips the two
baseline-comparison phases (paged-vs-dense and batched-prefill: each
needs extra engines / wall-based baseline rounds) — the CI gate covers
the metrics both files share.
"""
from __future__ import annotations

import json
import os
import statistics
import threading
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SLOTS = 4
MAX_LEN = 64
PAGE_SIZE = 8
PROMPT_LEN = 12
TTFT_MAX_NEW = 40
TTFT_ROUNDS = 4
# Saturation for the gated ttft ratio, mirroring the proxy gate's --cap:
# past this streaming has decisively won and the remaining variance is
# few-ms scheduler jitter in the denominator, not hot-path signal (the
# regression the gate exists to catch drops the ratio to ~1).
TTFT_CAP = 10.0
# Mixed-length workload: every static batch is held hostage by a 48-token
# straggler while its short sequences idle; continuous batching refills
# those slots immediately.  Longs lead so the continuous engine overlaps
# every straggler from the start.
MIX_MAX_NEW = (48, 2, 48, 2, 2, 48, 2, 48)


def _streams(tag: str):
    """Fresh request/response topics on a unique namespace."""
    from repro.core.connectors import new_key
    from repro.core.store import Store
    from repro.core.streaming import (
        QueuePublisher,
        QueueSubscriber,
        StreamConsumer,
        StreamProducer,
    )

    ns = f"sb-{tag}-{new_key()}"
    req_store = Store(f"{ns}-req")
    resp_store = Store(f"{ns}-resp")
    return (
        StreamProducer(QueuePublisher(ns), {"requests": req_store}),
        StreamConsumer(QueueSubscriber("requests", ns), timeout=60.0),
        StreamProducer(QueuePublisher(ns), {"responses": resp_store}),
        StreamConsumer(QueueSubscriber("responses", ns), timeout=60.0),
    )


def _send(producer, rng, req_id: str, max_new: int, sent_at=None):
    prompt = rng.integers(1, 200, PROMPT_LEN).astype(np.int32)
    if sent_at is not None:
        sent_at[req_id] = time.perf_counter()
    producer.send(
        "requests",
        {"prompt": prompt},
        metadata={"req_id": req_id, "max_new_tokens": max_new},
    )
    producer.flush_topic("requests")


def _make_engine(spec_self_draft: bool = False, **kw):
    import jax

    from repro.configs import get_smoke_config
    from repro.dist.sharding import materialize_params
    from repro.models.api import build_model
    from repro.serve.engine import ServeEngine, serve_context

    cfg = get_smoke_config("smollm-135m")
    ctx = serve_context(cfg)
    model = build_model(ctx)
    params = materialize_params(model.param_specs(), jax.random.PRNGKey(0))
    if spec_self_draft:
        # the acceptance-maximizing degenerate draft: the target itself
        kw.update(spec_k=SPEC_K, draft_model=model, draft_params=params)
    kw.setdefault("slots", SLOTS)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("page_size", PAGE_SIZE)
    return ServeEngine(ctx, params, eos_id=-1, **kw)


def _ttft_round(engine, tag: str) -> tuple[float, float]:
    """One round: SLOTS concurrent requests; returns (median ttft,
    median completion), client-observed."""
    from repro.serve.client import ServeClient

    producer, consumer, resp_prod, resp_cons = _streams(tag)
    rng = np.random.default_rng(1)
    sent_at: dict[str, float] = {}
    client = ServeClient(resp_cons)
    collector = threading.Thread(target=client.collect, daemon=True)
    collector.start()
    for i in range(SLOTS):
        _send(producer, rng, f"t{i}", TTFT_MAX_NEW, sent_at)
    producer.close_topic("requests")
    engine.run(consumer, resp_prod)
    collector.join(timeout=60)
    assert not collector.is_alive(), "response collector wedged"
    ttft = client.ttft_s(sent_at)
    total = client.completion_s(sent_at)
    assert len(ttft) == len(total) == SLOTS
    return statistics.median(ttft.values()), statistics.median(total.values())


def bench_ttft(engine, metrics: dict) -> None:
    """Streamed first token vs full completion, client-observed.

    TTFT is a few-ms latency floor observed across a thread boundary, so
    a single GIL switch-interval hiccup can double it; like the stream
    bench's wake latency (min of batch medians), the gate takes the best
    of a few rounds — latency floors are load-stable — and shrinks the
    interpreter's switch interval while measuring.
    """
    import sys

    old_interval = sys.getswitchinterval()
    sys.setswitchinterval(1e-3)
    try:
        rounds = [
            _ttft_round(engine, f"ttft{r}") for r in range(TTFT_ROUNDS)
        ]
    finally:
        sys.setswitchinterval(old_interval)
    raw = max(total / ttft for ttft, total in rounds)
    metrics["ttft_speedup"] = min(raw, TTFT_CAP)
    metrics["info_ttft_speedup_raw"] = raw
    metrics["info_ttft_s"] = min(ttft for ttft, _ in rounds)
    metrics["info_completion_s"] = statistics.median(t for _, t in rounds)


def bench_continuous_vs_static(engine, metrics: dict) -> None:
    """Same mixed-length workload: slots-refill-on-finish vs batch-drain.

    The gated ratio is the *decode-step count* static batching spends over
    continuous batching — the scheduling win itself, deterministic and
    load-free (per-step cost flatness is what ``slot_scaling_ratio``
    gates).  Wall-clock ratio is recorded as info alongside.
    """
    rng = np.random.default_rng(2)

    # continuous: all requests queued, slots refill as sequences finish
    producer, consumer, _, _ = _streams("cont")
    for i, mn in enumerate(MIX_MAX_NEW):
        _send(producer, rng, f"c{i}", mn)
    producer.close_topic("requests")
    steps0 = engine.metrics["decode_steps"]
    t0 = time.perf_counter()
    engine.run(consumer, max_requests=len(MIX_MAX_NEW))
    wall_cont = time.perf_counter() - t0
    steps_cont = engine.metrics["decode_steps"] - steps0

    # static: admit a full batch, drain it, only then admit the next
    producer, consumer, _, _ = _streams("stat")
    steps0 = engine.metrics["decode_steps"]
    t0 = time.perf_counter()
    for start in range(0, len(MIX_MAX_NEW), SLOTS):
        batch = MIX_MAX_NEW[start : start + SLOTS]
        for j, mn in enumerate(batch):
            _send(producer, rng, f"s{start + j}", mn)
        engine.run(consumer, max_requests=len(batch), close_responses=False)
    producer.close_topic("requests")
    wall_static = time.perf_counter() - t0
    steps_static = engine.metrics["decode_steps"] - steps0

    metrics["continuous_vs_static_ratio"] = steps_static / steps_cont
    metrics["info_continuous_wall_ratio"] = wall_static / wall_cont
    tokens = sum(mn for mn in MIX_MAX_NEW)
    metrics["info_tokens_per_s_continuous"] = tokens / wall_cont


SCALING_ROUNDS = 3
SCALING_MAX_NEW = 32


def _scaling_round(engine, r: int) -> tuple[float, float]:
    """(batched tokens/s, serial tokens/s) for one round."""
    rng = np.random.default_rng(3)
    max_new = SCALING_MAX_NEW

    producer, consumer, _, _ = _streams(f"par{r}")
    for i in range(SLOTS):
        _send(producer, rng, f"p{r}.{i}", max_new)
    producer.close_topic("requests")
    t0 = time.perf_counter()
    engine.run(consumer, max_requests=SLOTS)
    tps_batched = SLOTS * max_new / (time.perf_counter() - t0)

    producer, consumer, _, _ = _streams(f"ser{r}")
    t0 = time.perf_counter()
    for i in range(SLOTS):
        _send(producer, rng, f"q{r}.{i}", max_new)
        engine.run(consumer, max_requests=1, close_responses=False)
    producer.close_topic("requests")
    tps_serial = SLOTS * max_new / (time.perf_counter() - t0)
    return tps_batched, tps_serial


def bench_slot_scaling(engine, metrics: dict) -> None:
    """tokens/s with all slots hot vs the same requests served serially.

    Short phases make a single ratio jittery on a throttled box; the gate
    takes the median of a few rounds (each ratio still same-run)."""
    rounds = [_scaling_round(engine, r) for r in range(SCALING_ROUNDS)]
    metrics["slot_scaling_ratio"] = statistics.median(
        b / s for b, s in rounds
    )
    metrics["info_tokens_per_s_batched"] = max(b for b, _ in rounds)


# Long-context engine pair for the paging claim: at PD_MAX_LEN the dense
# step attends over the full cache while the paged step gathers only the
# ≤ PD_PROMPT+PD_MAX_NEW tokens each sequence occupies.
PD_MAX_LEN = 2048
PD_MAX_NEW = 48
PD_ROUNDS = 2


def _throughput_round(engine, tag: str, max_new: int) -> float:
    """tokens/s for one slots-wide round on ``engine`` (no responses)."""
    producer, consumer, _, _ = _streams(tag)
    rng = np.random.default_rng(1)
    for i in range(SLOTS):
        _send(producer, rng, f"{tag}.{i}", max_new)
    producer.close_topic("requests")
    t0 = time.perf_counter()
    engine.run(consumer, max_requests=SLOTS)
    return SLOTS * max_new / (time.perf_counter() - t0)


def bench_paged_vs_dense(pd_engines, metrics: dict) -> None:
    """Same params, same long-context workload: paged pool vs dense
    layout, back-to-back (load cancels in the ratio)."""
    paged, dense = pd_engines
    tps = {}
    for name, eng in (("paged", paged), ("dense", dense)):
        rounds = [
            _throughput_round(eng, f"pd-{name}{r}", PD_MAX_NEW)
            for r in range(PD_ROUNDS)
        ]
        tps[name] = statistics.median(rounds)
    metrics["paged_vs_dense_decode_ratio"] = tps["paged"] / tps["dense"]
    metrics["info_tokens_per_s_paged_long"] = tps["paged"]
    metrics["info_tokens_per_s_dense_long"] = tps["dense"]


BP_ROUNDS = 3


def bench_batched_prefill(engine, metrics: dict) -> None:
    """Admission wall for a slots-sized backlog: one-at-a-time prefill vs
    ONE padded prefill + one multi-page insert (max_new=1 keeps the
    workload prefill-only; both modes hit warm compilations)."""
    walls = {True: [], False: []}
    seq = [True, False] * BP_ROUNDS
    for r, mode in enumerate(seq):
        engine.batch_prefill = mode
        producer, consumer, _, _ = _streams(f"bp{r}")
        rng = np.random.default_rng(1)
        for i in range(SLOTS):
            _send(producer, rng, f"bp{r}.{i}", 1)
        producer.close_topic("requests")
        t0 = time.perf_counter()
        engine.run(consumer, max_requests=SLOTS)
        walls[mode].append(time.perf_counter() - t0)
    engine.batch_prefill = True
    batched = statistics.median(walls[True])
    serial = statistics.median(walls[False])
    metrics["batched_prefill_speedup"] = serial / batched
    metrics["info_batched_admit_wall_ms"] = batched * 1e3


PREFIX_TOKENS = 64
_prefix_round = [0]  # unique req_ids across the 3 suite repetitions


def bench_prefix_sharing(engine, metrics: dict) -> None:
    """Fresh pages allocated for prompts sharing a 64-token system prefix,
    sharing off vs on — pure allocator arithmetic via direct admission
    (no threads, no timers: the same numbers every run)."""
    from repro.serve.engine import Request

    rng = np.random.default_rng(4)
    shared = rng.integers(1, 200, PREFIX_TOKENS).astype(np.int32)

    def admit_four(tag: str, share: bool) -> int:
        engine.share_prefixes = share
        before = engine.pages.pages_allocated_total
        for i in range(SLOTS):
            prompt = np.concatenate(
                [shared, rng.integers(1, 200, 8).astype(np.int32)]
            )
            engine.admit(
                Request(req_id=f"{tag}{i}", prompt=prompt, max_new_tokens=8),
                i,
            )
        used = engine.pages.pages_allocated_total - before
        for i in range(SLOTS):
            engine._finish(i)
        return used

    r = _prefix_round[0]
    _prefix_round[0] += 1
    pages_shared = admit_four(f"pfx-on{r}-", True)
    pages_unshared = admit_four(f"pfx-off{r}-", False)
    engine.share_prefixes = True
    metrics["prefix_pages_saved_ratio"] = pages_unshared / pages_shared
    metrics["info_prefix_pages_shared_run"] = float(pages_shared)
    metrics["info_prefix_pages_unshared_run"] = float(pages_unshared)


SPEC_K = 3
SPEC_MAX_NEW = 32


def bench_spec_decode(engine, spec_engine, metrics: dict) -> None:
    """Speculative decode acceptance, straight off the engine counters.

    Both sides are deterministic step arithmetic (no timers): the
    speculative engine serves a slots-wide workload and the gated rate is
    accepted-tokens / slot-steps; the SAME workload on the plain engine
    yields the decode-step ratio the speculation is worth (info — it is
    rate/1 by construction, kept for the trajectory record).  With a
    self-draft the rate sits near the spec_k+1 ceiling; a broken
    draft/verify path collapses it to exactly 1.0."""
    rng = np.random.default_rng(5)

    p0 = engine.metrics["decode_steps"]
    producer, consumer, _, _ = _streams("specbase")
    for i in range(SLOTS):
        _send(producer, rng, f"sb{i}", SPEC_MAX_NEW)
    producer.close_topic("requests")
    engine.run(consumer, max_requests=SLOTS)
    plain_steps = engine.metrics["decode_steps"] - p0

    m0 = dict(spec_engine.metrics)
    producer, consumer, _, _ = _streams("spec")
    for i in range(SLOTS):
        _send(producer, rng, f"sp{i}", SPEC_MAX_NEW)
    producer.close_topic("requests")
    t0 = time.perf_counter()
    spec_engine.run(consumer, max_requests=SLOTS)
    wall = time.perf_counter() - t0
    accepted = (
        spec_engine.metrics["spec_accepted_tokens"] - m0["spec_accepted_tokens"]
    )
    slot_steps = (
        spec_engine.metrics["spec_slot_steps"] - m0["spec_slot_steps"]
    )
    spec_steps = spec_engine.metrics["decode_steps"] - m0["decode_steps"]
    metrics["spec_accepted_tokens_per_step"] = accepted / slot_steps
    metrics["info_spec_vs_plain_decode_steps"] = plain_steps / spec_steps
    metrics["info_spec_tokens_per_s"] = SLOTS * SPEC_MAX_NEW / wall
    assert spec_engine.pages.pages_in_use() == 0, "spec bench leaked KV pages"
    assert spec_engine.draft_pages.pages_in_use() == 0, (
        "spec bench leaked draft pages"
    )


FLEET_REQUESTS = 48
FLEET_MAX_NEW = 32
FLEET_ROUNDS = 2


def bench_fleet(metrics: dict) -> None:
    """Aggregate tokens/s vs engine count, same-run ratio (see module
    docstring: on one CPU share the gate is router-overhead flatness, not
    parallel speedup).  Each side is a full fleet: store server, router,
    subprocess engines, real ServeClient.  Three processes on one CPU
    share make a single pairing jittery, so — like the ttft gate's
    best-of-rounds — the gate takes the best pairing: the regression it
    exists to catch (a serializing or proxy-resolving router) collapses
    every round, while scheduler weather only dents some."""
    from repro.launch.fleet import run_fleet

    kw = dict(requests=FLEET_REQUESTS, max_new=FLEET_MAX_NEW, slots=2,
              ttl=5.0)
    pairs = [
        (run_fleet(1, **kw), run_fleet(2, **kw)) for _ in range(FLEET_ROUNDS)
    ]
    ratios = [t["tokens_per_s"] / o["tokens_per_s"] for o, t in pairs]
    best = max(range(FLEET_ROUNDS), key=lambda i: ratios[i])
    one, two = pairs[best]
    metrics["fleet_scaling"] = ratios[best]
    metrics["info_fleet_tokens_per_s_1eng"] = one["tokens_per_s"]
    metrics["info_fleet_tokens_per_s_2eng"] = two["tokens_per_s"]
    metrics["info_fleet_p99_ttft_s"] = two["p99_ttft_s"]
    counts = list(two["per_engine"].values())
    metrics["info_fleet_balance_min_max"] = min(counts) / max(counts)


def run_suite(engine=None, pd_engines=None, prefix_engine=None,
              spec_engine=None, fleet: bool = False) -> dict:
    engine = engine or _make_engine()
    # warmup: compile prefill/admit/decode outside every timed phase
    producer, consumer, _, _ = _streams("warm")
    rng = np.random.default_rng(0)
    for i in range(SLOTS):
        _send(producer, rng, f"w{i}", 4)
    producer.close_topic("requests")
    engine.run(consumer)

    metrics: dict[str, float] = {}
    bench_ttft(engine, metrics)
    bench_continuous_vs_static(engine, metrics)
    bench_slot_scaling(engine, metrics)
    if prefix_engine is not None:
        bench_prefix_sharing(prefix_engine, metrics)
        assert prefix_engine.pages.pages_in_use() == 0, "prefix bench leaked"
    if spec_engine is not None:  # quick too: the CI gate covers acceptance
        bench_spec_decode(engine, spec_engine, metrics)
    if fleet:  # quick too: fleet_scaling is a required CI gate
        bench_fleet(metrics)
    if pd_engines is not None:  # full runs only: the baseline comparisons
        bench_batched_prefill(engine, metrics)
        bench_paged_vs_dense(pd_engines, metrics)
        for e in pd_engines:
            assert e.pages.pages_in_use() == 0, "pd bench leaked KV pages"
    assert engine.pages.pages_in_use() == 0, "bench leaked KV pages"
    return metrics


def main(quick: bool = False) -> dict:
    runs = 1 if quick else 3
    engine = _make_engine()  # one engine: jit once, every phase warm
    prefix_engine = _make_engine(max_len=128, page_size=8)
    spec_engine = _make_engine(spec_self_draft=True)
    _throughput_round(spec_engine, "spec-warm", 8)  # compile draft/verify
    pd_engines = None
    if not quick:
        pd_engines = (
            _make_engine(max_len=PD_MAX_LEN, page_size=16, paged=True),
            _make_engine(max_len=PD_MAX_LEN, page_size=16, paged=False),
        )
        for r, e in enumerate(pd_engines):  # compile outside the timed rounds
            _throughput_round(e, f"pd-warm{r}", PD_MAX_NEW)
    samples = [
        run_suite(engine, pd_engines=pd_engines, prefix_engine=prefix_engine,
                  spec_engine=spec_engine, fleet=True)
        for _ in range(runs)
    ]
    metrics = {
        name: statistics.median(s[name] for s in samples) for name in samples[0]
    }
    name = "BENCH_serve.quick.json" if quick else "BENCH_serve.json"
    path = os.path.join(REPO, name)
    with open(path, "w") as f:
        json.dump(
            {
                "bench": "serve_bench",
                "quick": quick,
                "runs": runs,
                "unix_time": time.time(),
                "metrics": metrics,
            },
            f,
            indent=1,
        )
    for k, v in metrics.items():
        print(f"[serve_bench] {k:>28}: {v:,.3f}")
    print(f"[serve_bench] wrote {path}")
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="single run into BENCH_serve.quick.json (CI gate)")
    args = ap.parse_args()
    main(quick=args.quick)
