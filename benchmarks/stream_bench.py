"""BENCH_stream — streaming & futures hot-path trajectory artifact.

Companion to ``proxy_overhead``'s BENCH_proxy.json: one machine-readable
JSON per PR generation capturing the event-driven hot paths this repo
gates (``scripts/check.sh`` + ``scripts/compare_bench.py --stream``).

This box is CPU-share throttled, so absolute rates swing ~2× with
neighbor load; every gated metric is therefore a *same-run ratio* (both
sides measured back-to-back, so load cancels — the same trick the proxy
gate's proxy-vs-value ratios use).  Absolute rates are recorded with an
``info_`` prefix, which ``compare_bench`` reports but never gates.

Gated metrics:

- ``wake_latency_us``       — min-of-batch-medians in-memory blocking-
  resolve wake-up: consumer resume after the producer's ``put`` returns
  (futex wake + GIL handoff + zero-copy resolve).  Lower is better; the
  pre-notification poll loop floored this at ``poll_min`` (100 µs) and
  backed off to 10 ms.  Latency floors are load-stable.
- ``queue_vs_pickle_ratio`` — in-process broker events/s via the shared-
  dict fast path over the same loop via the legacy pickled-event path.
- ``filelog_vs_naive_ratio``— file-log drain rate of the batched
  persistent-handle reader over a naive open/seek/read×2/close-per-event
  reader (the pre-PR-3 algorithm), capped at 5.0 for the gate: the raw
  ratio (kept as ``info_filelog_vs_naive_raw``) is dominated by
  filesystem weather in the naive denominator and drifts 10-100× across
  boxes, while the real failure mode collapses the ratio to ~1.
- ``speedup_<size>``        — fig6 ProxyStream TPS over direct pub/sub
  TPS at each item size (dispatcher-bound regime; the paper's Fig 6
  metric, and the acceptance criterion: ≥1.0 at 100 kB, ≥2 at 5 MB).
- ``fig5_f05_ideal_ratio``  — ideal-pipelined makespan over measured
  ProxyFuture makespan at f=0.5 (1.0 = perfect overlap; ≥0.909 = within
  the 10% acceptance bound).

Full runs repeat the suite three times and commit the element-wise median
(``BENCH_stream.json``); ``--quick`` runs once into
``BENCH_stream.quick.json`` for the CI gate.
"""
from __future__ import annotations

import json
import os
import pickle
import statistics
import threading
import time

from benchmarks.fig5_pipelining import (
    N_TASKS,
    TASK_S,
    run_proxy,
    run_proxyfuture,
)
from benchmarks.fig6_streaming import SIZES, run_direct, run_proxystream
from repro.core import Store
from repro.core.connectors import new_key
from repro.core.streaming import (
    FileLogPublisher,
    FileLogSubscriber,
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WAKE_REPS = 60
QUEUE_EVENTS = 3000
FILELOG_EVENTS = 3000


def bench_wake_latency_us() -> float:
    """Blocking-resolve wake latency over an in-memory channel.

    Min of three batch medians: the wake path is one futex round + GIL
    handoff, so per-batch medians still carry scheduler weather; the best
    batch is the achievable latency this build delivers (and is what the
    25% gate can hold steady).
    """
    store = Store(f"wake-{new_key()}")
    batch_medians = []
    for _ in range(5):
        lats = []
        for _ in range(WAKE_REPS // 3):
            key = new_key()
            t: dict = {}
            started = threading.Event()

            def waiter():
                started.set()
                store.resolve(key, block=True, timeout=5)
                t["wake"] = time.perf_counter()

            th = threading.Thread(target=waiter)
            th.start()
            started.wait()
            # let the waiter reach the condition sleep (bench staging)
            time.sleep(0.0005)  # proxylint: disable=no-sleep-poll
            store.put(b"x", key=key)
            t_set = time.perf_counter()
            th.join()
            lats.append(max(0.0, t["wake"] - t_set) * 1e6)
        batch_medians.append(statistics.median(lats))
    store.close()
    return min(batch_medians)


class _PickleOnlyPublisher:
    """QueuePublisher with the obj fast path hidden: the legacy
    pickled-event broker path, used as the same-run ratio denominator."""

    def __init__(self, namespace: str):
        self._pub = QueuePublisher(namespace)

    def send_event(self, topic: str, event: bytes) -> None:
        self._pub.send_event(topic, event)

    def close(self) -> None:
        self._pub.close()


def _queue_rate(publisher, ns: str, events: int) -> float:
    store = Store(f"evq-store-{new_key()}")
    producer = StreamProducer(publisher, {"t": store}, evict_on_resolve=False)
    consumer = StreamConsumer(QueueSubscriber("t", ns), timeout=5)
    for _ in range(50):  # warmup
        producer.send("t", 0)
        consumer.next_with_metadata()
    best = 0.0
    for _ in range(3):
        t0 = time.perf_counter()
        for i in range(events):
            producer.send("t", i)
            consumer.next_with_metadata()
        best = max(best, events / (time.perf_counter() - t0))
    store.close()
    return best


def bench_queue(metrics: dict) -> None:
    """Shared-dict event loop vs the legacy pickled-event loop."""
    ns_fast, ns_legacy = f"evq-{new_key()}", f"evl-{new_key()}"
    fast = _queue_rate(QueuePublisher(ns_fast), ns_fast, QUEUE_EVENTS)
    legacy = _queue_rate(_PickleOnlyPublisher(ns_legacy), ns_legacy, QUEUE_EVENTS)
    metrics["info_events_per_s_queue"] = fast
    metrics["queue_vs_pickle_ratio"] = fast / legacy


def _naive_drain_rate(topic: str, tmpdir: str, events: int) -> float:
    """The pre-PR-3 reader: reopen + seek + 2 reads + close per event."""
    path = os.path.join(tmpdir, f"{topic}.log")
    offset = 0
    t0 = time.perf_counter()
    for _ in range(events):
        with open(path, "rb") as f:
            f.seek(offset)
            n = int.from_bytes(f.read(8), "little")
            payload = f.read(n)
            assert len(payload) == n
            offset += 8 + n
    return events / (time.perf_counter() - t0)


def bench_filelog(metrics: dict, tmpdir: str) -> None:
    """Batched persistent-handle drain vs the naive per-event reader."""
    pub = FileLogPublisher(tmpdir)
    event = b"e" * 64
    for _ in range(FILELOG_EVENTS):
        pub.send_event("drain", event)
    best = 0.0
    for _ in range(3):
        sub = FileLogSubscriber("drain", tmpdir)
        t0 = time.perf_counter()
        for _ in range(FILELOG_EVENTS):
            sub.next_event(timeout=5)
        best = max(best, FILELOG_EVENTS / (time.perf_counter() - t0))
        sub.close()
    naive = _naive_drain_rate("drain", tmpdir, FILELOG_EVENTS)
    metrics["info_events_per_s_filelog"] = best
    raw = best / naive
    metrics["info_filelog_vs_naive_raw"] = raw
    # Gate on min(raw, 5.0): the raw ratio mostly measures how slow the
    # NAIVE reader is on the current filesystem — page cache and open()
    # weather swing the denominator 10-100× between boxes (113× at the
    # PR-3 baseline vs ~7× here), which is drift the gate must ignore.
    # The regression it exists to catch — losing the batched
    # persistent-handle drain — collapses the ratio to ~1, far below any
    # capped baseline; the uncapped value stays visible as info_.
    metrics["filelog_vs_naive_ratio"] = min(raw, 5.0)


FILE_PUTS = 200


def bench_file_put(metrics: dict, tmpdir: str) -> None:
    """FileConnector cross-process put rate: fsync-per-object ``put_parts``
    vs ``put_batch``'s one-directory-fsync-per-batch durability point.
    Absolute rates only (``info_``): fsync latency is pure filesystem
    weather, so neither number is gated — the batch win is just recorded
    as the trajectory artifact for the durability-batching change."""
    from repro.core import FileConnector

    payload = b"p" * 4096
    c = FileConnector(os.path.join(tmpdir, "puts"))
    try:
        for i in range(20):  # warm the directory + page cache
            c.put_parts(f"w{i}", (payload,))
        t0 = time.perf_counter()
        for i in range(FILE_PUTS):
            c.put_parts(f"s{i}", (payload,))
        single = FILE_PUTS / (time.perf_counter() - t0)
        items = [(f"b{i}", (payload,)) for i in range(FILE_PUTS)]
        t0 = time.perf_counter()
        c.put_batch(items)
        batched = FILE_PUTS / (time.perf_counter() - t0)
    finally:
        c.close()
    metrics["info_file_put_per_s"] = single
    metrics["info_file_put_batch_per_s"] = batched


def bench_fig5_f05_ideal_ratio() -> float:
    from concurrent.futures import ThreadPoolExecutor

    f = 0.5
    ideal = TASK_S + (N_TASKS - 1) * (1 - f) * TASK_S
    with Store(f"sb5-{new_key()}") as store, ThreadPoolExecutor(N_TASKS) as pool:
        run_proxy(f, pool, store)  # warm the pool/store before timing
        t_pf = run_proxyfuture(f, pool, store)
    return ideal / t_pf


def run_suite() -> dict:
    import shutil
    import tempfile

    metrics: dict[str, float] = {}
    metrics["wake_latency_us"] = bench_wake_latency_us()
    bench_queue(metrics)
    d = tempfile.mkdtemp(prefix="stream-bench-")
    try:
        bench_filelog(metrics, d)
        bench_file_put(metrics, d)
    finally:
        shutil.rmtree(d, ignore_errors=True)
    for size in SIZES:
        tps_ps = run_proxystream(size)
        tps_d = run_direct(size)
        metrics[f"info_tps_{size}"] = tps_ps
        metrics[f"speedup_{size}"] = tps_ps / tps_d
    metrics["fig5_f05_ideal_ratio"] = bench_fig5_f05_ideal_ratio()
    return metrics


def main(quick: bool = False) -> dict:
    runs = 1 if quick else 3
    samples = [run_suite() for _ in range(runs)]
    metrics = {
        name: statistics.median(s[name] for s in samples) for name in samples[0]
    }
    name = "BENCH_stream.quick.json" if quick else "BENCH_stream.json"
    path = os.path.join(REPO, name)
    with open(path, "w") as f:
        json.dump(
            {
                "bench": "stream_bench",
                "quick": quick,
                "runs": runs,
                "unix_time": time.time(),
                "metrics": metrics,
            },
            f,
            indent=1,
        )
    for k, v in metrics.items():
        print(f"[stream_bench] {k:>26}: {v:,.2f}")
    print(f"[stream_bench] wrote {path}")
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="single run into BENCH_stream.quick.json (CI gate)")
    args = ap.parse_args()
    main(quick=args.quick)
