"""Benchmark orchestrator: one benchmark per paper figure/table.

    PYTHONPATH=src python -m benchmarks.run [--only fig5_pipelining ...]

Runs the paper-reproduction benchmarks (Fig 5 pipelining, Fig 6 streaming,
Fig 7 memory, §III proxy-overhead threshold), prints each table + validated
claims, and — if dry-run roofline JSONs exist under results/ — prints the
roofline summary table (§Roofline of EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

BENCHES = ("fig5_pipelining", "fig6_streaming", "fig7_memory", "proxy_overhead")


def run_roofline_summary() -> None:
    from repro.analysis.roofline import RooflineReport, report_table

    root = os.path.join(os.path.dirname(__file__), "..", "results")
    paths = sorted(glob.glob(os.path.join(root, "dryrun_*.json")))
    if not paths:
        return
    print("\n== roofline (from dry-run artifacts) ==")
    for path in paths:
        with open(path) as f:
            recs = json.load(f)
        reports = [
            RooflineReport(**r["roofline"])
            for r in recs
            if r.get("status") == "ok" and r.get("probes")
            # probe-extrapolated records only: multipod rows are the
            # compile/sharding proof, their raw scanned costs are not a
            # roofline (cost_analysis visits scan bodies once)
        ]
        if reports:
            print(f"-- {os.path.basename(path)} --")
            print(report_table(reports))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", choices=BENCHES)
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)

    import importlib

    failures = 0
    for name in args.only or BENCHES:
        mod = importlib.import_module(f"benchmarks.{name}")
        print(f"\n[bench] running {name} ...", flush=True)
        result = mod.main()
        print(result.dump())
        result.save()
        # benchmarks that track a repo-root perf-trajectory artifact expose
        # write_bench_json; the driver stays benchmark-agnostic
        emit = getattr(mod, "write_bench_json", None)
        if emit is not None:
            print(f"[bench] wrote {emit(result)}")
        if not result.ok:
            failures += 1
    if not args.skip_roofline:
        run_roofline_summary()
    print(f"\n[bench] done; {failures} benchmark(s) with failed claims")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
