"""Paper §III claim: proxy benefits outweigh overhead above ~10 kB.

Measures, per object size:
- **pass-by-value**: payload serialized into the task and result out (what a
  control-flow engine does);
- **proxy**: Store.proxy() creation + just-in-time resolution in the task.

The crossover where proxy total cost beats pass-by-value is reported; the
paper places it around 10 kB (connector-dependent).
"""
from __future__ import annotations

import pickle
import time

from benchmarks.common import BenchResult, payload
from repro.core import Store
from repro.core.proxy import extract

SIZES = (1_000, 10_000, 100_000, 1_000_000, 10_000_000)
REPS = 20
QUICK_SIZES = (1_000, 100_000, 1_000_000)
QUICK_REPS = 5


def main(quick: bool = False) -> BenchResult:
    sizes = QUICK_SIZES if quick else SIZES
    reps = QUICK_REPS if quick else REPS
    res = BenchResult("proxy_overhead")
    crossover = None
    with Store("overhead") as store:
        for size in sizes:
            obj = payload(size)
            t0 = time.perf_counter()
            for _ in range(reps):
                blob = pickle.dumps(obj)          # into task payload
                got = pickle.loads(blob)
                _ = pickle.loads(pickle.dumps(got))  # result path back
            t_value = (time.perf_counter() - t0) / reps

            t0 = time.perf_counter()
            for _ in range(reps):
                p = store.proxy(obj, evict_on_resolve=True)
                _ = extract(p)                    # just-in-time resolve
            t_proxy = (time.perf_counter() - t0) / reps

            res.add(bytes=size, pass_by_value_s=t_value, proxy_s=t_proxy,
                    ratio=t_value / t_proxy)
            if crossover is None and t_proxy <= t_value:
                crossover = size
    res.claim(
        crossover is not None and crossover <= 100_000,
        f"proxy wins by ≤100 kB objects (paper: ~10 kB; crossover here: "
        f"{crossover if crossover else f'>{sizes[-1]}'} B)",
    )
    big = res.rows[-1]
    res.claim(
        big["ratio"] > 1.0,
        f"{big['bytes'] // 1_000_000} MB objects: proxy {big['ratio']:.1f}× "
        f"cheaper than pass-by-value",
    )
    return res


def write_bench_json(res: BenchResult, *, quick: bool = False) -> str:
    """Machine-readable perf-trajectory artifact at the repo root.

    One JSON per PR generation; the driver diffs successive BENCH_proxy.json
    files to track the proxy hot path over time.  Quick (CI-smoke) runs
    write a separate file so 5-rep noise never clobbers the full-run
    trajectory point.
    """
    import json
    import os
    import time as _time

    name = "BENCH_proxy.quick.json" if quick else "BENCH_proxy.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        json.dump(
            {
                "bench": res.name,
                "quick": quick,
                "unix_time": _time.time(),
                "rows": res.rows,
                "claims": res.claims,
                "ok": res.ok,
            },
            f,
            indent=1,
        )
    return os.path.abspath(path)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes/reps for the CI smoke (scripts/check.sh)")
    args = ap.parse_args()
    r = main(quick=args.quick)
    print(r.dump())
    r.save()
    print(f"[bench] wrote {write_bench_json(r, quick=args.quick)}")
    # quick mode is a CI smoke: 5-rep timings are informational, so only a
    # crash fails the gate; full runs still report claim status via exit code
    sys.exit(0 if (r.ok or args.quick) else 1)
