"""Paper §III claim: proxy benefits outweigh overhead above ~10 kB.

Measures, per object size:
- **pass-by-value**: payload serialized into the task and result out (what a
  control-flow engine does);
- **proxy**: Store.proxy() creation + just-in-time resolution in the task;
- **breakdown**: serialize (framed encode), transport (connector put +
  get_view), deserialize (zero-copy decode) — where the proxy path spends
  its time;
- **cold vs warm resolve**: first resolution vs a resolve-cache hit.

The crossover where proxy total cost beats pass-by-value is reported; the
paper places it around 10 kB (connector-dependent).
"""
from __future__ import annotations

import gc
import pickle
import time

from benchmarks.common import BenchResult, payload
from repro.core import Store, framing
from repro.core.connectors import get_payload, put_payload
from repro.core.proxy import extract, reset

SIZES = (1_000, 10_000, 100_000, 1_000_000, 10_000_000)
REPS = 20
QUICK_SIZES = (1_000, 100_000, 1_000_000)
QUICK_REPS = 10  # enough reps that the compare_bench gate sees signal, not noise
WARMUP = 3


def _best(fn, reps: int, trials: int = 3) -> float:
    """Best-of-``trials`` mean op time: robust to allocator/GC outliers,
    which otherwise dominate the MB-scale pass-by-value loops."""
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def measure_rows(quick: bool = False) -> list[dict]:
    """One measurement pass: a row of timings per object size.

    Collector pauses land inside individual timing loops and widen the
    ratio dispersion (the gated quantity) far more than they shift its
    centre, so the whole pass runs with GC off; nothing here allocates
    cycles, so refcounting still frees the payload churn promptly.
    """
    sizes = QUICK_SIZES if quick else SIZES
    rows: list[dict] = []
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        rows = _measure_rows_inner(sizes, quick)
    finally:
        if gc_was_enabled:
            gc.enable()
    return rows


def _measure_rows_inner(sizes, quick: bool) -> list[dict]:
    rows: list[dict] = []
    with Store("overhead") as store:
        for size in sizes:
            # sub-100-µs round trips need more reps for a stable ratio — and
            # quick mode needs *more* of them than the full run, not fewer:
            # it is a single cold-process pass gated against the warmed
            # median baseline, so its small-size loops carry the dispersion
            # budget.  At large sizes quick mode keeps the full rep count
            # (still fast) so its ratios stay comparable to the baseline.
            if size <= 100_000:
                reps = 300 if quick else REPS * 10
            else:
                reps = REPS
            obj = payload(size)
            for _ in range(WARMUP):
                _ = pickle.loads(pickle.dumps(obj))
                _ = extract(store.proxy(obj, evict_on_resolve=True))

            def by_value(obj=obj):
                blob = pickle.dumps(obj)          # into task payload
                got = pickle.loads(blob)
                _ = pickle.loads(pickle.dumps(got))  # result path back

            def by_proxy(obj=obj):
                p = store.proxy(obj, evict_on_resolve=True)
                _ = extract(p)                    # just-in-time resolve

            t_value = _best(by_value, reps)
            t_proxy = _best(by_proxy, reps)

            # -- hot-path breakdown: where the proxy round trip goes --------
            t0 = time.perf_counter()
            for _ in range(reps):
                parts = framing.encode(obj)
            t_ser = (time.perf_counter() - t0) / reps

            conn = store.connector
            t0 = time.perf_counter()
            for _ in range(reps):
                put_payload(conn, "bd", parts)
                pl = get_payload(conn, "bd")  # parts tuple or contiguous view
                conn.evict("bd")  # mirrors the evict_on_resolve round trip
            t_tra = (time.perf_counter() - t0) / reps

            put_payload(conn, "bd", parts)
            pl = get_payload(conn, "bd")
            t0 = time.perf_counter()
            for _ in range(reps):
                _ = framing.decode(pl)
            t_des = (time.perf_counter() - t0) / reps
            del pl
            conn.evict("bd")

            # -- resolve cache: cold first hit vs warm re-resolve -----------
            p = store.proxy(obj)
            t0 = time.perf_counter()
            _ = extract(p)
            t_cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            for _ in range(reps):
                reset(p)
                _ = extract(p)                    # resolve-cache hit
            t_warm = (time.perf_counter() - t0) / reps
            store.evict(object.__getattribute__(p, "__proxy_metadata__")["key"])

            rows.append(dict(
                bytes=size, pass_by_value_s=t_value, proxy_s=t_proxy,
                ratio=t_value / t_proxy,
                serialize_s=t_ser, transport_s=t_tra, deserialize_s=t_des,
                resolve_cold_s=t_cold, resolve_warm_s=t_warm,
                warm_speedup=t_cold / t_warm))
    return rows


def measure_metrics(quick: bool = False) -> dict:
    """PR 9 tier/network metrics (the size/ratio rows stay untouched).

    - ``multi_route_overhead_ratio``: direct InMemory put+get time over the
      same round trip through a two-tier ``MultiConnector`` (~1 kB payload,
      hot-tier route).  Gated higher-is-better: 1.0 means routing is free;
      a collapse means the policy/route-map fast path regressed.
    - ``info_net_roundtrip_us``: 1 kB put+get against an in-process
      ``StoreServer`` over real TCP.  Absolute wall time on a shared box —
      informational, never gated.
    """
    from repro.core.connectors import InMemoryConnector, new_key
    from repro.core.connectors_net import StoreServer, StoreServerConnector
    from repro.core.multi import MultiConnector, Tier

    reps = 200 if quick else 1000
    blob = bytes(payload(1_000))

    direct = InMemoryConnector(new_key())

    def d_roundtrip():
        direct.put("k", blob)
        _ = direct.get("k")

    multi = MultiConnector([
        Tier("hot", InMemoryConnector(new_key()), max_bytes=100_000),
        Tier("cold", InMemoryConnector(new_key())),
    ])

    def m_roundtrip():
        multi.put("k", blob)
        _ = multi.get("k")

    for _ in range(WARMUP):
        d_roundtrip()
        m_roundtrip()
    # Interleave the direct/multi trials: the gated value is their *ratio*,
    # and on a CPU-share-throttled box a single scheduler burst can cover
    # three consecutive trials of one side (the loops are ~ms-scale),
    # skewing the ratio while both absolute times stay plausible.  With
    # alternating trials a burst has to hit every trial of one side and
    # none of the other to bias the min/min ratio.
    t_direct = float("inf")
    t_multi = float("inf")
    gc_was_enabled = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(reps):
                d_roundtrip()
            t_direct = min(t_direct, (time.perf_counter() - t0) / reps)
            t0 = time.perf_counter()
            for _ in range(reps):
                m_roundtrip()
            t_multi = min(t_multi, (time.perf_counter() - t0) / reps)
    finally:
        if gc_was_enabled:
            gc.enable()
    direct.close()
    multi.close()

    server = StoreServer(backing=InMemoryConnector(new_key()))
    server.start()
    net = StoreServerConnector(server.address, namespace="bench")

    def n_roundtrip():
        net.put("k", blob)
        _ = net.get("k")

    for _ in range(WARMUP):
        n_roundtrip()
    t_net = _best(n_roundtrip, reps // 4 or 1)
    net.close()
    server.stop()

    return {
        "multi_route_overhead_ratio": t_direct / t_multi,
        "info_net_roundtrip_us": t_net * 1e6,
    }


def main(quick: bool = False, runs: int = 1) -> BenchResult:
    """Measure (``runs`` passes, element-wise median) and validate claims.

    The committed BENCH_proxy.json baseline is produced with ``--runs 3``
    so claims and rows come from the *same* merged data.
    """
    import statistics

    all_rows = [measure_rows(quick) for _ in range(runs)]
    all_metrics = [measure_metrics(quick) for _ in range(runs)]
    rows = []
    for idx in range(len(all_rows[0])):
        merged = {
            k: (all_rows[0][idx][k] if k == "bytes"
                else statistics.median(r[idx][k] for r in all_rows))
            for k in all_rows[0][idx]
        }
        rows.append(merged)
    res = BenchResult("proxy_overhead")
    res.rows = rows
    res.metrics = {
        k: statistics.median(m[k] for m in all_metrics) for k in all_metrics[0]
    }
    sizes = tuple(r["bytes"] for r in rows)
    crossover = None
    for r in rows:
        if crossover is None and r["proxy_s"] <= r["pass_by_value_s"]:
            crossover = r["bytes"]
    res.claim(
        crossover is not None and crossover <= 10_000,
        f"proxy wins by ≤10 kB objects (paper: ~10 kB; crossover here: "
        f"{crossover if crossover else f'>{sizes[-1]}'} B)",
    )
    big = res.rows[-1]
    res.claim(
        big["ratio"] > 1.0,
        f"{big['bytes'] // 1_000_000} MB objects: proxy {big['ratio']:.1f}× "
        f"cheaper than pass-by-value",
    )
    # The in-memory cold path is itself zero-copy now (parts pass-by-
    # reference), so the cache's edge over cold compressed from ~10× to the
    # residual frame-parse + frombuffer cost it still skips.
    warm_target = 1.5 if quick else 2.0
    res.claim(
        big["warm_speedup"] >= warm_target,
        f"resolve cache: warm re-resolve {big['warm_speedup']:.1f}× faster "
        f"than the zero-copy cold resolve at {big['bytes'] // 1_000_000} MB "
        f"(target ≥{warm_target:.1f}×)",
    )
    route_ratio = res.metrics["multi_route_overhead_ratio"]
    res.claim(
        route_ratio >= 0.25,
        f"tier routing: MultiConnector round trip within 4× of a direct "
        f"InMemory round trip at 1 kB (ratio {route_ratio:.2f}, target ≥0.25)",
    )
    return res


def write_bench_json(res: BenchResult, *, quick: bool = False,
                     runs: int = 1) -> str:
    """Machine-readable perf-trajectory artifact at the repo root.

    One JSON per PR generation; the driver diffs successive BENCH_proxy.json
    files to track the proxy hot path over time.  Quick (CI-smoke) runs
    write a separate file so 5-rep noise never clobbers the full-run
    trajectory point.
    """
    import json
    import os
    import time as _time

    name = "BENCH_proxy.quick.json" if quick else "BENCH_proxy.json"
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        json.dump(
            {
                "bench": res.name,
                "quick": quick,
                "runs": runs,  # rows are element-wise medians across runs
                "unix_time": _time.time(),
                "rows": res.rows,
                "metrics": getattr(res, "metrics", {}),
                "claims": res.claims,
                "ok": res.ok,
            },
            f,
            indent=1,
        )
    return os.path.abspath(path)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes/reps for the CI smoke (scripts/check.sh)")
    ap.add_argument("--runs", type=int, default=1,
                    help="measurement passes; rows are element-wise medians "
                         "(the committed baseline uses 3)")
    args = ap.parse_args()
    r = main(quick=args.quick, runs=args.runs)
    print(r.dump())
    r.save()
    print(f"[bench] wrote {write_bench_json(r, quick=args.quick, runs=args.runs)}")
    # quick mode is a CI smoke: 5-rep timings are informational, so only a
    # crash fails the gate; full runs still report claim status via exit code
    sys.exit(0 if (r.ok or args.quick) else 1)
