"""Paper §III claim: proxy benefits outweigh overhead above ~10 kB.

Measures, per object size:
- **pass-by-value**: payload serialized into the task and result out (what a
  control-flow engine does);
- **proxy**: Store.proxy() creation + just-in-time resolution in the task.

The crossover where proxy total cost beats pass-by-value is reported; the
paper places it around 10 kB (connector-dependent).
"""
from __future__ import annotations

import pickle
import time

from benchmarks.common import BenchResult, payload
from repro.core import Store
from repro.core.proxy import extract

SIZES = (1_000, 10_000, 100_000, 1_000_000, 10_000_000)
REPS = 20


def main() -> BenchResult:
    res = BenchResult("proxy_overhead")
    crossover = None
    with Store("overhead") as store:
        for size in SIZES:
            obj = payload(size)
            t0 = time.perf_counter()
            for _ in range(REPS):
                blob = pickle.dumps(obj)          # into task payload
                got = pickle.loads(blob)
                _ = pickle.loads(pickle.dumps(got))  # result path back
            t_value = (time.perf_counter() - t0) / REPS

            t0 = time.perf_counter()
            for _ in range(REPS):
                p = store.proxy(obj, evict_on_resolve=True)
                _ = extract(p)                    # just-in-time resolve
            t_proxy = (time.perf_counter() - t0) / REPS

            res.add(bytes=size, pass_by_value_s=t_value, proxy_s=t_proxy,
                    ratio=t_value / t_proxy)
            if crossover is None and t_proxy <= t_value:
                crossover = size
    res.claim(
        crossover is not None and crossover <= 100_000,
        f"proxy wins by ≤100 kB objects (paper: ~10 kB; crossover here: "
        f"{crossover if crossover else '>10MB'} B)",
    )
    big = res.rows[-1]
    res.claim(
        big["ratio"] > 1.0,
        f"10 MB objects: proxy {big['ratio']:.1f}× cheaper than pass-by-value",
    )
    return res


if __name__ == "__main__":
    r = main()
    print(r.dump())
    r.save()
