"""Paper Fig 6: scalable stream processing with ProxyStream.

One producer streams items of size d; a dispatcher consumes the stream and
submits a compute task per item to a worker pool.  Configurations:

- **direct** (the paper's Redis Pub/Sub): bulk data flows THROUGH the
  dispatcher — it receives + deserializes each item, re-serializes it into
  the task payload.
- **proxystream**: the dispatcher consumes *metadata only* and forwards
  proxies; bulk bytes go store → worker, bypassing the dispatcher.

Metric: completed compute tasks per second.  Paper: 4.6×/6.2× faster than
Redis Pub/Sub at 1/10 MB and 256 workers; dispatcher caps at ~100 MB/s.
Scaled here: 16 workers, 0.02 s tasks, 100 kB–5 MB items — like the paper's
256-worker runs, the worker pool outpaces the dispatcher, so throughput is
set by how much bulk data squeezes through the event path.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from concurrent.futures import ThreadPoolExecutor, wait

from benchmarks.common import BenchResult, Timer, payload
from repro.core import Store
from repro.core.proxy import Proxy, extract
from repro.core.streaming import (
    QueuePublisher,
    QueueSubscriber,
    StreamConsumer,
    StreamProducer,
)

WORKERS = 16
TASK_S = 0.02
ITEMS = 96
SIZES = (100_000, 1_000_000, 5_000_000)


def _compute(item) -> int:
    if isinstance(item, Proxy):
        item = extract(item)  # bulk resolves HERE, in the worker
    time.sleep(TASK_S)
    return len(item)


def run_direct(d: int, items: int = None, workers: int = None) -> float:
    """Bulk bytes through the dispatcher (pub/sub semantics)."""
    items = items or ITEMS
    workers = workers or WORKERS
    q: queue.Queue = queue.Queue(maxsize=8)
    item = payload(d)

    def producer():
        for _ in range(items):
            q.put(pickle.dumps(item))  # broker carries the full item
        q.put(None)

    done = []
    with ThreadPoolExecutor(workers) as pool, Timer() as t:
        threading.Thread(target=producer, daemon=True).start()
        futs = []
        while True:
            blob = q.get()
            if blob is None:
                break
            obj = pickle.loads(blob)            # dispatcher deserializes
            task_payload = pickle.dumps(obj)    # …and re-serializes
            futs.append(pool.submit(lambda b: _compute(pickle.loads(b)), task_payload))
        done = [f.result() for f in futs]
    assert all(done)
    return items / t.elapsed


def run_proxystream(d: int, items: int = None, workers: int = None) -> float:
    """Metadata through the dispatcher; bulk store→worker."""
    items = items or ITEMS
    workers = workers or WORKERS
    ns = f"fig6-{d}"
    store = Store(f"fig6-store-{d}")
    producer = StreamProducer(
        QueuePublisher(ns), {"items": store}, evict_on_resolve=True
    )
    consumer = StreamConsumer(QueueSubscriber("items", ns), timeout=30.0)
    item = payload(d)

    def produce():
        for i in range(items):
            producer.send("items", item, metadata={"i": i})
            producer.flush_topic("items")
        producer.close_topic("items")

    with ThreadPoolExecutor(workers) as pool, Timer() as t:
        threading.Thread(target=produce, daemon=True).start()
        futs = [pool.submit(_compute, proxy) for proxy in consumer]
        wait(futs)
        assert all(f.result() for f in futs)
    store.close()
    return items / t.elapsed


def main() -> BenchResult:
    res = BenchResult("fig6_streaming")
    for d in SIZES:
        tps_direct = run_direct(d)
        tps_ps = run_proxystream(d)
        res.add(
            item_bytes=d, direct_tps=tps_direct, proxystream_tps=tps_ps,
            speedup=tps_ps / tps_direct,
        )
    small, large = res.rows[0], res.rows[-1]
    res.claim(
        small["speedup"] >= 1.0,
        f"small items (100 kB): ProxyStream at least matches direct pub/sub "
        f"(paper: ≈equal; got {small['speedup']:.2f}×)",
    )
    res.claim(
        large["speedup"] >= 2.0,
        f"large items ({large['item_bytes']//1_000_000} MB): ProxyStream ≥2× "
        f"direct pub/sub (paper: 4.6–7.3× at cluster scale; got "
        f"{large['speedup']:.2f}× at {WORKERS} workers)",
    )
    res.claim(
        large["speedup"] > small["speedup"],
        "advantage grows with item size (paper Fig 6 trend)",
    )
    return res


if __name__ == "__main__":
    r = main()
    print(r.dump())
    r.save()
