"""Shared helpers for the paper-figure benchmarks.

Constants are scaled down from the paper's Polaris runs (1 s tasks, 10–100 MB
payloads, 256 workers) to a single-core CI box; every constant is exposed so
the paper-scale values can be restored on a real cluster.  EXPERIMENTS.md
records both the scaled defaults and the paper's originals.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def payload(nbytes: int, seed: int = 0) -> np.ndarray:
    """Arbitrary Python object of ~nbytes (numpy array, like the paper)."""
    return np.random.default_rng(seed).integers(
        0, 255, max(nbytes, 8) // 8, dtype=np.int64
    )


def store_bytes(connector) -> int:
    """Bytes currently held in a connector (the memory-trace metric)."""
    return sum(len(connector.get(k) or b"") for k in connector.keys())


@dataclass
class BenchResult:
    name: str
    rows: list[dict] = field(default_factory=list)
    claims: list[str] = field(default_factory=list)  # validated paper claims

    def add(self, **row):
        self.rows.append(row)

    def claim(self, ok: bool, text: str):
        self.claims.append(f"[{'PASS' if ok else 'FAIL'}] {text}")

    def dump(self) -> str:
        lines = [f"== {self.name} =="]
        if self.rows:
            keys = list(self.rows[0])
            lines.append(",".join(keys))
            for r in self.rows:
                lines.append(",".join(_fmt(r[k]) for k in keys))
        lines += self.claims
        return "\n".join(lines)

    def save(self):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(os.path.join(RESULTS_DIR, f"{self.name}.json"), "w") as f:
            json.dump({"rows": self.rows, "claims": self.claims}, f, indent=1)

    @property
    def ok(self) -> bool:
        return all(c.startswith("[PASS]") for c in self.claims)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
