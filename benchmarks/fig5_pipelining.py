"""Paper Fig 5: task pipelining with ProxyFutures.

n tasks in sequence; each sleeps f·s (startup overhead), resolves its input,
then sleeps (1−f)·s and produces d bytes for its successor.  Deployments:

- **no-proxy**: task i is submitted when i−1's result has returned to the
  client; data rides the task payload (serialized twice, like an engine).
- **proxy**: sequential submission, but data moves via Store proxies.
- **proxyfuture**: ALL tasks submitted immediately; task i holds a proxy of
  i−1's future and blocks just-in-time — overheads pipeline (paper Fig 3).

Paper: n=8, s=1 s, d=10 MB, Dask+Redis on Polaris; ideal reduction ≈ f·(n−1)/n,
observed 19.6% at f=0.2.  Scaled here: s=0.25 s, d=1 MB (constants below).
"""
from __future__ import annotations

import pickle
import time
from concurrent.futures import ThreadPoolExecutor

from benchmarks.common import BenchResult, Timer, payload
from repro.core import Store
from repro.core.futures import ProxyFuture
from repro.core.proxy import Proxy, extract

N_TASKS = 6
TASK_S = 0.25
DATA_BYTES = 1_000_000
FRACTIONS = (0.0, 0.2, 0.5, 0.8)


def _task(fraction: float, data_in, out_future: ProxyFuture | None):
    """One pipeline stage: overhead → resolve input → compute → produce."""
    time.sleep(fraction * TASK_S)  # startup overhead (imports, model load)
    if isinstance(data_in, Proxy):
        data = extract(data_in)  # blocks just-in-time for proxyfuture
    else:
        data = data_in
    time.sleep((1.0 - fraction) * TASK_S)  # compute
    out = payload(DATA_BYTES)
    if out_future is not None:
        out_future.set_result(out)
        return None
    return out


def run_no_proxy(f: float, pool: ThreadPoolExecutor) -> float:
    with Timer() as t:
        data = payload(DATA_BYTES)
        for _ in range(N_TASKS):
            # engine serializes the payload into the task and the result out
            blob = pickle.dumps(data)
            fut = pool.submit(_task, f, pickle.loads(blob), None)
            data = pickle.loads(pickle.dumps(fut.result()))
    return t.elapsed


def run_proxy(f: float, pool: ThreadPoolExecutor, store: Store) -> float:
    with Timer() as t:
        data_proxy = store.proxy(payload(DATA_BYTES))
        for _ in range(N_TASKS):
            fut = pool.submit(_task, f, data_proxy, None)
            data_proxy = store.proxy(fut.result())
    return t.elapsed


def run_proxyfuture(f: float, pool: ThreadPoolExecutor, store: Store) -> float:
    with Timer() as t:
        first = store.future()
        first.set_result(payload(DATA_BYTES))
        futures = [store.future() for _ in range(N_TASKS)]
        chain = [first] + futures
        handles = [
            pool.submit(_task, f, chain[i].proxy(), futures[i])
            for i in range(N_TASKS)
        ]
        futures[-1].result()
        for h in handles:
            h.result()
    return t.elapsed


def main() -> BenchResult:
    res = BenchResult("fig5_pipelining")
    with Store("fig5") as store, ThreadPoolExecutor(N_TASKS) as pool:
        for f in FRACTIONS:
            t_np = run_no_proxy(f, pool)
            t_p = run_proxy(f, pool, store)
            t_pf = run_proxyfuture(f, pool, store)
            seq_ideal = N_TASKS * TASK_S
            pipe_ideal = TASK_S + (N_TASKS - 1) * (1 - f) * TASK_S
            res.add(
                f=f, no_proxy=t_np, proxy=t_p, proxyfuture=t_pf,
                ideal_sequential=seq_ideal, ideal_pipelined=pipe_ideal,
                reduction=1 - t_pf / t_p,
            )
    rows = {r["f"]: r for r in res.rows}
    r02, r05, r08 = rows[0.2], rows[0.5], rows[0.8]
    # paper claims: pipelining ≈ ideal; reduction grows with f
    res.claim(
        r02["proxyfuture"] < r02["proxy"] * 0.92,
        f"f=0.2: ProxyFuture reduces makespan ≥8% vs sequential proxy "
        f"(paper: 19.6% at n=8; got {r02['reduction']:.1%} at n={N_TASKS})",
    )
    res.claim(
        r08["reduction"] > r02["reduction"],
        f"reduction grows with overhead fraction "
        f"({r02['reduction']:.1%} @0.2 → {r08['reduction']:.1%} @0.8)",
    )
    res.claim(
        r02["proxyfuture"] < r02["ideal_pipelined"] * 1.25,
        f"f=0.2 ProxyFuture within 25% of ideal pipeline "
        f"({r02['proxyfuture']:.2f}s vs {r02['ideal_pipelined']:.2f}s ideal)",
    )
    res.claim(
        r05["proxyfuture"] < r05["ideal_pipelined"] * 1.10,
        f"f=0.5 ProxyFuture within 10% of ideal pipeline — wake-ups are "
        f"notification-driven, not polled "
        f"({r05['proxyfuture']:.2f}s vs {r05['ideal_pipelined']:.2f}s ideal)",
    )
    return res


if __name__ == "__main__":
    r = main()
    print(r.dump())
    r.save()
