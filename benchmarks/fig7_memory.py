"""Paper Fig 7: memory management over a simulated MapReduce workflow.

Rounds of map-reduce: each mapper receives its input via proxy and produces
an output consumed by one reducer.  Memory-management models:

- **default**: proxies created, targets never freed → store grows linearly.
- **manual**: programmer evicts each key after its consumer finishes
  (requires a-priori knowledge of the data flow).
- **ownership**: OwnedProxy per object; references passed to tasks go out of
  scope with the task, owners freed when rounds end — automatic.

Metric: bytes held in the mediated store, sampled after every round (the
deterministic analogue of the paper's RSS trace).  Paper: default grows
monotonically; ownership == manual.  Paper constants: 8 rounds × 32 mappers
× 100 MB in / 10 MB out.  Scaled: 4 rounds × 8 mappers × 4 MB / 0.4 MB.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

from benchmarks.common import BenchResult, payload, store_bytes
from repro.core import Store
from repro.core.ownership import borrow, free, owned_proxy, release
from repro.core.proxy import Proxy, extract

ROUNDS = 4
MAPPERS = 8
MAP_IN = 4_000_000
MAP_OUT = 400_000


def _mapper(inp) -> object:
    data = extract(inp) if isinstance(inp, Proxy) else inp
    return payload(MAP_OUT, seed=int(data[0]) % 7)


def _reducer(parts) -> int:
    return sum(
        int((extract(p) if isinstance(p, Proxy) else p)[0]) for p in parts
    )


def run(model: str) -> list[int]:
    """Run the workflow under one memory model; return per-round store bytes."""
    store = Store(f"fig7-{model}")
    trace = []
    with ThreadPoolExecutor(MAPPERS) as pool:
        for rnd in range(ROUNDS):
            inputs = [payload(MAP_IN, seed=rnd * MAPPERS + i) for i in range(MAPPERS)]
            if model == "ownership":
                owners = [owned_proxy(store, x) for x in inputs]
                refs = [borrow(o) for o in owners]
                futs = [pool.submit(_mapper, r) for r in refs]
                outs = [f.result() for f in futs]
                for r in refs:
                    release(r)  # task completed → reference out of scope
                out_owners = [owned_proxy(store, o) for o in outs]
                out_refs = [borrow(o) for o in out_owners]
                _reducer(out_refs)
                for r in out_refs:
                    release(r)
                # round ends: owners go out of scope → targets evicted
                for o in owners + out_owners:
                    free(o)
            else:
                proxies = [store.proxy(x) for x in inputs]
                futs = [pool.submit(_mapper, p) for p in proxies]
                outs = [f.result() for f in futs]
                out_proxies = [store.proxy(o) for o in outs]
                _reducer(out_proxies)
                if model == "manual":
                    for p in proxies + out_proxies:
                        store.evict(p.__factory__.key)
            trace.append(store_bytes(store.connector))
    store.close()
    return trace


def main() -> BenchResult:
    res = BenchResult("fig7_memory")
    traces = {m: run(m) for m in ("default", "manual", "ownership")}
    for rnd in range(ROUNDS):
        res.add(
            round=rnd,
            default_bytes=traces["default"][rnd],
            manual_bytes=traces["manual"][rnd],
            ownership_bytes=traces["ownership"][rnd],
        )
    d, m, o = traces["default"], traces["manual"], traces["ownership"]
    res.claim(
        all(d[i] > d[i - 1] for i in range(1, ROUNDS)),
        f"default leaks monotonically ({d[0]/1e6:.0f} → {d[-1]/1e6:.0f} MB)",
    )
    res.claim(
        o[-1] == m[-1] == 0,
        f"ownership == manual == fully reclaimed at end "
        f"(ownership {o[-1]} B, manual {m[-1]} B)",
    )
    res.claim(
        max(o) <= max(d) / ROUNDS * 1.5,
        f"ownership peak ({max(o)/1e6:.0f} MB) ≪ default final ({d[-1]/1e6:.0f} MB)",
    )
    return res


if __name__ == "__main__":
    r = main()
    print(r.dump())
    r.save()
