#!/usr/bin/env python
"""CLI for ProxyLint (see repro.analysis.lint for the rule docs).

    python scripts/proxy_lint.py [paths...] [--json] [--select rules] [--list-rules]

Exits non-zero when any violation is reported — scripts/check.sh runs
this as a named gate step.
"""
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
