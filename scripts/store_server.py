#!/usr/bin/env python
"""Thin wrapper: ``python scripts/store_server.py`` == ``python -m repro.launch.store_server``."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.store_server import main

if __name__ == "__main__":
    sys.exit(main())
