#!/usr/bin/env python
"""Perf-trajectory gate: fail on hot-path regression vs committed baselines.

Two modes:

- default: compares a freshly produced quick proxy benchmark
  (``BENCH_proxy.quick.json``) against the committed full-run baseline
  (``BENCH_proxy.json``) at every object size both runs cover.  A fresh
  proxy-vs-value ratio more than ``--tolerance`` (default 25%) below the
  baseline ratio at any size fails the check.  The proxy bench also
  carries a metric dict (tier-routing overhead, network round trip),
  compared with the same rules as the metric modes below.
- ``--stream``: compares ``BENCH_stream.quick.json`` against the committed
  ``BENCH_stream.json`` metric-by-metric.  Gated metrics are same-run
  ratios (load-immune on a CPU-share-throttled box) plus the wake latency;
  metrics prefixed ``info_`` (absolute rates) are printed but never gated.
  Metrics named ``*_us``/``*_s``/``*_latency*`` are lower-is-better (a rise
  beyond tolerance fails); everything else is higher-is-better.
- ``--serve``: same metric-dictionary comparison for the serving gate
  (``BENCH_serve.quick.json`` vs committed ``BENCH_serve.json``):
  streamed-vs-complete TTFT speedup, continuous-vs-static batching,
  slot-count throughput scaling.

Either way the hot paths can only ratchet forward.

Usage: scripts/compare_bench.py [fresh.json] [baseline.json]
                                [--stream | --serve] [--tolerance 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_ratios(path: str) -> dict[int, float]:
    with open(path) as f:
        doc = json.load(f)
    return {int(r["bytes"]): float(r["ratio"]) for r in doc.get("rows", [])}


def load_metrics(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    return {k: float(v) for k, v in doc.get("metrics", {}).items()}


def _lower_is_better(name: str) -> bool:
    return name.endswith(("_us", "_s")) or "latency" in name


def compare_proxy(args) -> int:
    fresh, base = load_ratios(args.fresh), load_ratios(args.baseline)
    shared = sorted(set(fresh) & set(base))
    if not shared:
        print("[compare_bench] no shared sizes between fresh and baseline")
        return 1

    failed = False
    for size in shared:
        fresh_r = min(fresh[size], args.cap)
        base_r = min(base[size], args.cap)
        floor = base_r * (1.0 - args.tolerance)
        status = "OK " if fresh_r >= floor else "REGRESSION"
        failed |= fresh_r < floor
        print(f"[compare_bench] {size:>9} B: fresh ratio {fresh[size]:6.2f} "
              f"vs baseline {base[size]:6.2f} "
              f"(capped floor {floor:6.2f}) {status}")
    # PR 9: the proxy bench also carries a metric dict (tier routing +
    # network round trip) — gated with the same rules as --stream/--serve
    f_metrics, b_metrics = load_metrics(args.fresh), load_metrics(args.baseline)
    if f_metrics or b_metrics or args.require:
        rc = _compare_metric_dicts(f_metrics, b_metrics, args, "proxy/tier")
        failed |= rc != 0
    if failed:
        print(f"[compare_bench] FAIL: hot path regressed >"
              f"{args.tolerance:.0%} vs committed BENCH_proxy.json")
        return 1
    print("[compare_bench] OK: no ratio regression")
    return 0


def _compare_metric_dicts(fresh, base, args, what: str) -> int:
    missing = [n for n in args.require if n not in fresh or n not in base]
    if missing:
        for n in missing:
            print(f"[compare_bench] required metric {n!r} missing "
                  f"(fresh: {n in fresh}, baseline: {n in base})")
        print("[compare_bench] FAIL: a --require'd metric is absent — a "
              "gated metric silently disappearing is itself a regression")
        return 1
    shared = sorted(set(fresh) & set(base))
    if not shared:
        print("[compare_bench] no shared metrics between fresh and baseline")
        return 1

    failed = False
    for name in shared:
        f_v, b_v = fresh[name], base[name]
        if name.startswith("info_"):
            print(f"[compare_bench] {name:>26}: fresh {f_v:12.2f} "
                  f"vs baseline {b_v:12.2f} (informational, not gated)")
            continue
        if _lower_is_better(name):
            limit = b_v * (1.0 + args.tolerance)
            ok = f_v <= limit
            bound = f"ceil {limit:12.2f}"
        else:
            limit = b_v * (1.0 - args.tolerance)
            ok = f_v >= limit
            bound = f"floor {limit:11.2f}"
        failed |= not ok
        print(f"[compare_bench] {name:>26}: fresh {f_v:12.2f} "
              f"vs baseline {b_v:12.2f} ({bound}) "
              f"{'OK' if ok else 'REGRESSION'}")
    if failed:
        print(f"[compare_bench] FAIL: {what} hot path regressed >"
              f"{args.tolerance:.0%} vs committed baseline")
        return 1
    print(f"[compare_bench] OK: no {what} metric regression")
    return 0


def compare_metrics(args, what: str) -> int:
    return _compare_metric_dicts(
        load_metrics(args.fresh), load_metrics(args.baseline), args, what
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="?", default=None)
    ap.add_argument("baseline", nargs="?", default=None)
    ap.add_argument("--stream", action="store_true",
                    help="compare BENCH_stream metric dictionaries instead "
                         "of BENCH_proxy size/ratio rows")
    ap.add_argument("--serve", action="store_true",
                    help="compare BENCH_serve metric dictionaries (serving "
                         "gate: ttft/continuous-batching/slot-scaling)")
    ap.add_argument("--require", action="append", default=[], metavar="NAME",
                    help="fail unless NAME is present in BOTH fresh and "
                         "baseline metric sets (repeatable; all modes — the "
                         "proxy bench carries a metric dict too) — pins a "
                         "gated metric so it cannot silently vanish from "
                         "the bench")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression vs baseline "
                         "(quick runs use few reps; leave headroom for noise)")
    ap.add_argument("--cap", type=float, default=10.0,
                    help="proxy mode: saturate ratios at this value before "
                         "comparing — beyond it the proxy has decisively won "
                         "and the variance is pass-by-value allocator noise, "
                         "not hot-path signal")
    args = ap.parse_args(argv)
    if args.stream and args.serve:
        ap.error("--stream and --serve are mutually exclusive")

    stem = ("BENCH_serve" if args.serve
            else "BENCH_stream" if args.stream else "BENCH_proxy")
    if args.fresh is None:
        args.fresh = os.path.join(REPO, f"{stem}.quick.json")
    if args.baseline is None:
        args.baseline = os.path.join(REPO, f"{stem}.json")

    if not os.path.exists(args.baseline):
        print(f"[compare_bench] no baseline at {args.baseline}; skipping")
        return 0
    if args.serve:
        return compare_metrics(args, "serving")
    if args.stream:
        return compare_metrics(args, "stream/futures")
    return compare_proxy(args)


if __name__ == "__main__":
    sys.exit(main())
