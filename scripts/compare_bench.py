#!/usr/bin/env python
"""Perf-trajectory gate: fail on proxy-vs-value ratio regression.

Compares a freshly produced quick benchmark (``BENCH_proxy.quick.json``)
against the committed full-run baseline (``BENCH_proxy.json``) at every
object size both runs cover.  A fresh ratio more than ``--tolerance``
(default 25%) below the baseline ratio at any size fails the check, so the
store/proxy hot path can only ratchet forward.

Usage: scripts/compare_bench.py [fresh.json] [baseline.json] [--tolerance 0.25]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_ratios(path: str) -> dict[int, float]:
    with open(path) as f:
        doc = json.load(f)
    return {int(r["bytes"]): float(r["ratio"]) for r in doc.get("rows", [])}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", nargs="?",
                    default=os.path.join(REPO, "BENCH_proxy.quick.json"))
    ap.add_argument("baseline", nargs="?",
                    default=os.path.join(REPO, "BENCH_proxy.json"))
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional ratio drop vs baseline "
                         "(quick runs use few reps; leave headroom for noise)")
    ap.add_argument("--cap", type=float, default=10.0,
                    help="saturate ratios at this value before comparing: "
                         "beyond it the proxy has decisively won and the "
                         "variance is pass-by-value allocator noise, not "
                         "hot-path signal")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"[compare_bench] no baseline at {args.baseline}; skipping")
        return 0
    fresh, base = load_ratios(args.fresh), load_ratios(args.baseline)
    shared = sorted(set(fresh) & set(base))
    if not shared:
        print("[compare_bench] no shared sizes between fresh and baseline")
        return 1

    failed = False
    for size in shared:
        fresh_r = min(fresh[size], args.cap)
        base_r = min(base[size], args.cap)
        floor = base_r * (1.0 - args.tolerance)
        status = "OK " if fresh_r >= floor else "REGRESSION"
        failed |= fresh_r < floor
        print(f"[compare_bench] {size:>9} B: fresh ratio {fresh[size]:6.2f} "
              f"vs baseline {base[size]:6.2f} "
              f"(capped floor {floor:6.2f}) {status}")
    if failed:
        print(f"[compare_bench] FAIL: hot path regressed >"
              f"{args.tolerance:.0%} vs committed BENCH_proxy.json")
        return 1
    print("[compare_bench] OK: no ratio regression")
    return 0


if __name__ == "__main__":
    sys.exit(main())
