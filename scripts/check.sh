#!/usr/bin/env bash
# Tier-1 gate + perf smoke.  Run from anywhere:
#
#     scripts/check.sh            # full tier-1 suite + quick proxy benchmark
#     scripts/check.sh --fast     # tier-1 only (skip the benchmark smoke)
#
# pytest picks up pythonpath/testpaths from pyproject.toml, so no PYTHONPATH
# export is needed for the suite; the benchmark runs as a module from the
# repo root with src/ on PYTHONPATH (mirrors how the dry-run is invoked).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== proxy-lint: static proxy-lifecycle rules =="
# ProxyLint (src/repro/analysis/lint.py): AST rules for the proxy
# anti-patterns this repo keeps re-litigating in review — sleep-polling,
# busy-wait loops on exists(), stale mutable-key reads, donated-buffer
# reuse, discarded ownership mints, swallowed errors.  Non-zero exit on
# any violation; suppressions are inline `# proxylint: disable=<rule>`
# pragmas so every exception is visible in the diff.  Runs in --fast
# mode too: it is the cheapest gate in this file.
python scripts/proxy_lint.py

echo
echo "== tier-1: pytest (under ProxySan) =="
# Subprocess/chaos tests (@pytest.mark.multiproc) run under a per-test
# SIGALRM watchdog (tests/conftest.py): a wedged child fails its test fast
# instead of hanging the whole gate.  This covers the serve suite's
# cross-process client/engine-restart tests too — they carry the same
# marker.  The env var is a hard CAP over every multiproc test's budget
# (including per-test overrides); 300 s bounds the gate's worst case while
# leaving the chaos suite slack on a loaded box.
#
# REPRO_PROXYSAN=1 runs the whole suite under the runtime sanitizer
# (src/repro/core/sanitize.py): any test that triggers a use-after-evict,
# double-free, refcount underflow, or stale cache read fails, and the
# session exits non-zero if an Owned cell is still resident at the end
# (tests/conftest.py session gate).
REPRO_MULTIPROC_TIMEOUT="${REPRO_MULTIPROC_TIMEOUT:-300}" \
    REPRO_PROXYSAN=1 \
    python -m pytest -x -q

echo
echo "== proxysan: cross-process smoke =="
# Named re-run of the sanitizer's multiproc smoke (also part of tier-1):
# a producer/consumer pair over a FileConnector, both processes under
# REPRO_PROXYSAN=1, both leak reports asserted clean — the sanitizer's
# own end-to-end contract stays visible in the gate output.
REPRO_PROXYSAN=1 python -m pytest -x -q tests/test_proxysan.py -k smoke

echo
echo "== kernels: Pallas interpret-mode vs jnp oracles =="
# The tier-1 run above already includes these, but an explicit named step
# keeps the kernel contract visible in the gate output: every Pallas
# kernel (flash/paged attention, wkv6, ssd) must match its pure-jnp
# reference in interpret mode on CPU — the only kernel validation this
# box can run (no TPU).
python -m pytest -x -q tests/test_kernels.py

echo
echo "== store server: cross-process lease/serve over TCP =="
# PR 9's acceptance bar as a named gate (also part of tier-1): a real
# store-server process with StoreServerConnector clients drives the lease
# service (SIGKILL chaos) and the serve delta/completion stream (engine
# restart) with zero changes to those layers — the network connector is
# the only moving part.
REPRO_PROXYSAN=1 python -m pytest -x -q tests/test_store_server.py \
    -k "lease or serve"

echo
echo "== serve: speculative decode bit-identity =="
# Spec decode's whole contract in one named gate (runs in --fast too):
# with a perfect self-draft AND with a draft built to always disagree,
# the engine's emitted tokens equal the target-only reference decode
# exactly — greedy rejection makes the output draft-independent by
# construction.  The multi-query verify kernel that backs it is pinned
# alongside (interpret-mode Pallas vs the dense staircase oracle).
python -m pytest -x -q tests/test_serve_spec.py tests/test_kernels.py \
    -k "bit_identical or multi_query"

echo
echo "== fleet: failover chaos matrix =="
# The serve-fleet acceptance bar as a named gate (also part of tier-1):
# N subprocess engines behind the front-end router, one SIGKILL'd before
# admission / mid-decode / after its completion commit but before the
# client read — every client transcript must stay bit-identical to the
# single-engine greedy reference with exactly one on_done per request
# (no loss, no duplicate), plus the dead-engine client-deadline pin.
REPRO_PROXYSAN=1 python -m pytest -x -q tests/test_fleet_chaos.py

if [[ "${1:-}" != "--fast" ]]; then
    echo
    echo "== perf smoke: proxy_overhead --quick =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.proxy_overhead --quick
    echo
    echo "== perf gate: quick ratios vs committed BENCH_proxy.json =="
    # --require pins the PR 9 tier-routing metric: the MultiConnector route
    # fast path silently vanishing from the bench is itself a failure.
    # 40% tolerance for the same reason as the stream gate below: the quick
    # run's first-in-process 100 kB reading routinely lands 20-30% under the
    # committed full-mode baseline on this CPU-share-throttled box, while
    # the regressions this gate exists to catch (proxy path broken, route
    # fast path lost) collapse the ratios far beyond 40%.
    python scripts/compare_bench.py --tolerance 0.4 \
        --require multi_route_overhead_ratio
    echo
    echo "== perf smoke: stream_bench --quick =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.stream_bench --quick
    echo
    echo "== perf gate: quick metrics vs committed BENCH_stream.json =="
    # 40% tolerance: this box is CPU-share throttled and even same-run
    # ratios carry scheduler weather; the regressions this gate exists to
    # catch (a reintroduced polling loop, a lost batching path) are step
    # functions far beyond 40%.
    python scripts/compare_bench.py --stream --tolerance 0.4
    echo
    echo "== perf smoke: serve_bench --quick =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.serve_bench --quick
    echo
    echo "== perf gate: quick metrics vs committed BENCH_serve.json =="
    # 25% tolerance is enough here: the serving metrics are same-run (or
    # deterministic step-count) ratios with large headroom over their
    # failure modes (streaming broken → ttft_speedup ~1 vs the 10× cap;
    # static batching → exactly 1.0 vs 1.88; serialized decode → ~1 vs
    # ~3.1-3.8; draft rejected every step → accepted/slot-step exactly
    # 1.0 vs the ≥1.5 gate).  --require pins the speculative-decode and
    # fleet metrics: dropping either from the bench is itself a gate
    # failure.  fleet_scaling is the router-overhead-flatness ratio (this
    # is a one-CPU box — see benchmarks/serve_bench.py): a router that
    # serialized forwarding or started resolving proxies collapses it.
    python scripts/compare_bench.py --serve --tolerance 0.25 \
        --require spec_accepted_tokens_per_step \
        --require fleet_scaling
fi

echo
echo "[check] OK"
