#!/usr/bin/env bash
# Tier-1 gate + perf smoke.  Run from anywhere:
#
#     scripts/check.sh            # full tier-1 suite + quick proxy benchmark
#     scripts/check.sh --fast     # tier-1 only (skip the benchmark smoke)
#
# pytest picks up pythonpath/testpaths from pyproject.toml, so no PYTHONPATH
# export is needed for the suite; the benchmark runs as a module from the
# repo root with src/ on PYTHONPATH (mirrors how the dry-run is invoked).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo
    echo "== perf smoke: proxy_overhead --quick =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.proxy_overhead --quick
    echo
    echo "== perf gate: quick ratios vs committed BENCH_proxy.json =="
    python scripts/compare_bench.py
fi

echo
echo "[check] OK"
